"""Scenario matrix (§3.4, §9): trace-driven & adversarial populations.

PRs 1–5 guarded the engines with 7 hand-written synthetic scenarios. This
matrix replaces them with ~24 declarative :class:`ScenarioSpec` cases —
the originals ported verbatim, plus trace-replayed availability (diurnal
timezone waves, heavy-tailed sessions, correlated outages fitted from the
bundled ``host_sessions.csv`` trace) and the hostile populations §3.4's
replication/adaptive-validation design exists to defeat: colluding
cliques, Sybil churn-and-rejoin identities, credit farmers,
availability-correlated failures.

Every case runs through :func:`repro.core.run_parity`: the batch
validation engine vs the scalar oracle AND the vectorized world loop vs
the scalar event loop must produce identical SimMetrics, server counts,
credit totals, per-instance validate states, and job states — then the
scenario's golden bounds are checked on the (provably shared) result.
All scenarios are deterministic from their spec's seed.

Key empirical finding pinned here (seed_sweep_* + clique_half_fleet):
quorum-2 replication rejects every fabricated result from *independent*
cheaters, and a 3-of-12 clique on an always-on fleet never wins — but
once availability starvation (trace replay) or clique mass (≥ half the
fleet) concentrates both replicas of a job inside the clique, matching
wrong payloads validate each other and quorum is defeated. Adaptive
replication does NOT close this hole; the §3.4 defense layer
(``DefensePolicy``: work-spreading suspicion clusters + HR classes +
host punishment) does — the ``*_defended`` scenarios pin the contained
bounds, and ``test_clique_defense_regression`` pins both sides of the
flip. The residual wrong-accepts in the defended goldens are wins
*finalized before the first suspicion signal exists* (hosts buffer a
day of work in the initial placement burst, long before any validation
completes); a reactive defense cannot reach those, and the bound is
pinned so a regression in either direction is loud.

The per-scenario reports are dumped to ``benchmarks/SCENARIO_report.json``
for the CI artifact.
"""
import json
import os

import pytest

from repro.core import (
    Clique,
    CreditFarm,
    DefensePolicy,
    Outage,
    ScenarioSpec,
    Sybil,
    TraceReplay,
    ValidateState,
    run_parity,
    run_spec,
    sybil_identity_ids,
)
from repro.core.scenarios import DAY, HOUR, SYBIL_ID_BASE, generate_population
from repro.data import toggles_to_intervals

REPORT_PATH = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "benchmarks",
    "SCENARIO_report.json",
)

_REPORTS = []


@pytest.fixture(scope="module", autouse=True)
def _report_sink():
    """Collect every scenario's golden-bound report; dump the artifact."""
    yield _REPORTS
    if _REPORTS:
        os.makedirs(os.path.dirname(REPORT_PATH), exist_ok=True)
        with open(REPORT_PATH, "w") as f:
            json.dump({"scenarios": sorted(_REPORTS, key=lambda r: r["name"])},
                      f, indent=2)


# ---------------------------------------------------------------------------
# the matrix: spec -> golden-bound check (run via the 3-axis parity harness)
# ---------------------------------------------------------------------------

SCENARIOS = {}


def scenario(spec):
    def register(check):
        assert spec.name not in SCENARIOS
        SCENARIOS[spec.name] = (spec, check)
        return check
    return register


# -- ported originals (PRs 1-5's hand-written matrix, now spec-declared) --

@scenario(ScenarioSpec(name="quiescence", horizon=3 * DAY))
def _check_quiescence(r):
    """Clean dedicated grid, generous horizon: everything validates and the
    plant goes quiescent at the quorum-2 overhead floor."""
    counts = r.server.counts()
    assert counts["jobs_success"] == 60
    assert counts["jobs_failure"] == 0
    assert r.metrics.error_rate == 0.0
    assert 2.0 <= r.metrics.replication_overhead <= 2.3
    assert counts["instances_in_progress"] == 0
    assert counts["instances_unsent"] == 0
    assert r.metrics.idle_fraction > 0.5


@scenario(ScenarioSpec(name="high_churn", n_hosts=16, churn_rate=1.0 / (1.5 * DAY),
                       horizon=5 * DAY, delay_bound=8 * HOUR, est_hours=1.5))
def _check_high_churn(r):
    """Hosts permanently depart mid-run (§4): deadlines fire, retries land
    on survivors, the work completes at an overhead premium."""
    assert r.server.counts()["jobs_success"] >= 56
    assert r.metrics.error_rate == 0.0
    assert 2.0 <= r.metrics.replication_overhead <= 2.5
    assert len(r.sim.specs) < 8  # most of the fleet actually left
    assert sum(t.metrics.timeouts for t in r.server.transitioners) > 0


@scenario(ScenarioSpec(name="malicious_independent", malicious_fraction=0.05,
                       error_prob=0.01, horizon=3 * DAY))
def _check_malicious_independent(r):
    """5% *independently* malicious volunteers (§3.4): quorum-2 replication
    rejects every fabricated result (contrast with the clique cases)."""
    assert r.metrics.wrong_accepted == 0
    assert r.metrics.error_rate == 0.0
    assert r.server.counts()["jobs_success"] >= 55
    assert r.metrics.replication_overhead > 2.0


@scenario(ScenarioSpec(name="cpu_gpu_mix", gpu=True, gpu_fraction=0.5,
                       n_jobs=80, est_hours=0.4))
def _check_cpu_gpu_mix(r):
    """Mixed CPU/GPU fleet (§3.1 plan classes) validates cross-device via
    the fuzzy comparator."""
    assert r.server.counts()["jobs_success"] == 80
    assert r.metrics.error_rate == 0.0
    gpu_versions = {
        v.id for v in r.server.store.apps["w"].versions
        if v.plan_class.name.startswith("gpu")
    }
    assert any(i.app_version_id in gpu_versions
               for i in r.server.store.instances.values())


@scenario(ScenarioSpec(name="low_availability", availability=0.6, horizon=4 * DAY))
def _check_low_availability(r):
    """~60% exponential availability (§1.1): throughput drops, correctness
    holds."""
    assert r.server.counts()["jobs_success"] >= 55
    assert r.metrics.error_rate == 0.0
    assert r.metrics.idle_fraction >= 0.35


@scenario(ScenarioSpec(name="error_prone", error_prob=0.05, horizon=3 * DAY))
def _check_error_prone(r):
    """Flaky hardware corrupting 5% of results: replication filters all of
    it."""
    assert r.metrics.wrong_accepted == 0
    assert r.server.counts()["jobs_success"] >= 55
    assert r.metrics.replication_overhead > 2.0
    assert any(i.validate_state == ValidateState.INVALID
               for i in r.server.store.instances.values())


# -- trace-driven availability (repro.data.traces replay) --

@scenario(ScenarioSpec(name="trace_diurnal_3tz", seed=5,
                       trace=TraceReplay(n_timezones=3), horizon=3 * DAY))
def _check_trace_diurnal_3tz(r):
    """Replayed trace availability, 3 timezone waves: the fleet is online
    ~2/3 of the time in rolling waves; work still completes cleanly."""
    assert all(s.avail_schedule is not None for s in r.population)
    assert r.server.counts()["jobs_success"] == 60
    assert r.metrics.wrong_accepted == 0
    assert 2.0 <= r.metrics.replication_overhead <= 2.2
    assert r.metrics.idle_fraction > 0.9


@scenario(ScenarioSpec(name="trace_single_tz", seed=6,
                       trace=TraceReplay(n_timezones=1), horizon=3 * DAY))
def _check_trace_single_tz(r):
    """One timezone: the whole fleet sleeps together — the worst-case
    diurnal trough — and the backlog still drains by the horizon."""
    assert r.server.counts()["jobs_success"] == 60
    assert r.metrics.wrong_accepted == 0
    assert r.metrics.replication_overhead <= 2.3


@scenario(ScenarioSpec(name="trace_heavy_tail", seed=7,
                       trace=TraceReplay(diurnal=False, scale=0.6), horizon=3 * DAY))
def _check_trace_heavy_tail(r):
    """Heavy-tailed lognormal sessions without the diurnal wave (pure
    session-length effect), compressed 0.6x for faster mixing."""
    assert r.server.counts()["jobs_success"] == 60
    assert r.metrics.wrong_accepted == 0
    assert r.metrics.replication_overhead <= 2.2


@scenario(ScenarioSpec(name="trace_outage", seed=5, trace=TraceReplay(n_timezones=3),
                       outage=Outage(start=0.75 * DAY, duration=6 * HOUR, fraction=0.5),
                       horizon=3 * DAY))
def _check_trace_outage(r):
    """Correlated outage on top of trace replay: half the fleet loses power
    simultaneously for 6h; the schedule splice keeps them all dark."""
    spec = r.spec
    dark = [s for s in r.population
            if not any(a < spec.outage.start + spec.outage.duration
                       and b > spec.outage.start
                       for a, b in toggles_to_intervals(s.avail_schedule, spec.horizon))]
    assert len(dark) >= spec.n_hosts // 2  # the hit half plus chance sleepers
    assert r.server.counts()["jobs_success"] == 60
    assert r.metrics.wrong_accepted == 0


@scenario(ScenarioSpec(name="blackout_half", seed=3,
                       outage=Outage(start=1.0 * DAY, duration=8 * HOUR, fraction=0.5),
                       horizon=3 * DAY))
def _check_blackout_half(r):
    """Outage layer on an otherwise always-on fleet: exactly the hit half
    gets a forced 8h window, everyone else never toggles."""
    scheduled = [s for s in r.population if s.avail_schedule is not None]
    assert len(scheduled) == 6
    assert all(s.avail_schedule == (1.0 * DAY, 1.0 * DAY + 8 * HOUR)
               for s in scheduled)
    assert r.server.counts()["jobs_success"] == 60
    assert r.metrics.wrong_accepted == 0


@scenario(ScenarioSpec(name="trace_adaptive", seed=5, trace=TraceReplay(n_timezones=2),
                       adaptive=True, n_jobs=80, horizon=3 * DAY))
def _check_trace_adaptive(r):
    """Adaptive replication under realistic availability: overhead still
    trends toward the §3.4 target without accepting errors."""
    assert r.server.counts()["jobs_success"] == 80
    assert r.metrics.wrong_accepted == 0
    assert r.metrics.replication_overhead <= 2.2


@scenario(ScenarioSpec(name="correlated_failures", seed=8,
                       trace=TraceReplay(n_timezones=3),
                       correlated_failures=0.3, horizon=3 * DAY))
def _check_correlated_failures(r):
    """Failures correlated with poor availability: the least-available
    quartile also corrupts 30% of its results (failing flash, dying PSU)."""
    flaky = [s for s in r.population if s.error_prob == 0.3]
    assert len(flaky) == r.spec.n_hosts // 4
    assert r.server.counts()["jobs_success"] == 60
    assert r.metrics.wrong_accepted == 0
    assert r.metrics.replication_overhead > 2.0  # corruption forced retries


# -- adversarial populations --

@scenario(ScenarioSpec(name="clique_pair", seed=2, clique=Clique(size=2), n_jobs=40))
def _check_clique_pair(r):
    """2-host clique vs quorum-2 on an always-on 12-host fleet: the
    scheduler's one-instance-per-host rule means both replicas must land on
    the 2 cliquers — never happens here; zero credit leaks."""
    assert r.metrics.wrong_accepted == 0
    assert r.clique_quorum_wins() == 0
    assert r.credit_of_hosts(r.clique_host_ids()) == 0.0
    assert r.server.counts()["jobs_success"] == 40


@scenario(ScenarioSpec(name="clique_triple_adaptive", seed=2, adaptive=True,
                       clique=Clique(size=3), n_jobs=40))
def _check_clique_triple_adaptive(r):
    """Satellite regression: 3-host clique with matching wrong payloads vs
    min_quorum=2 honest replicas, adaptive replication ON. Current
    behavior: always-cheating cliquers never build reputation, every job
    still replicates, and no wrong result wins quorum."""
    assert r.metrics.wrong_accepted == 0
    assert r.clique_quorum_wins() == 0
    assert r.credit_of_hosts(r.clique_host_ids()) == 0.0
    assert r.wrong_credit() == 0.0
    assert r.server.counts()["jobs_success"] == 40


@scenario(ScenarioSpec(name="clique_half_fleet", seed=2, clique=Clique(size=6),
                       n_jobs=40))
def _check_clique_half_fleet(r):
    """6-of-12 clique, defense OFF: with half the fleet colluding, both
    replicas of a job frequently land inside the clique and the matching
    wrong payloads validate each other — quorum is structurally defeated
    (seed-pinned golden; clique_half_fleet_defended pins the fix)."""
    assert r.metrics.wrong_accepted == 9
    assert r.clique_quorum_wins() == 9
    assert 0.0 < r.wrong_credit() <= 8.0
    assert r.server.counts()["jobs_success"] == 40


@scenario(ScenarioSpec(name="clique_half_fleet_defended", seed=2,
                       clique=Clique(size=6), n_jobs=40,
                       defense=DefensePolicy()))
def _check_clique_half_fleet_defended(r):
    """The flip: same 6-of-12 clique with the §3.4 defense layer ON. The
    clique's co-wins + losses against honest pairs turn its active members
    suspicious and cluster them; from then on same-cluster replicas count
    as ONE vote toward quorum, so every later collusion attempt is vetoed
    and re-validated against an honest tie-breaker. 9 defeated quorums
    drop to 1 — the single win finalized before the first loss signal
    existed (initial placement burst at t≈140, first validation t≈3420;
    see the module docstring for why that residual is structural)."""
    assert r.metrics.wrong_accepted == 1
    assert r.clique_quorum_wins() == 1
    assert 0.0 < r.wrong_credit() <= 1.0
    assert r.server.counts()["jobs_success"] == 40
    assert r.server.counts()["jobs_failure"] == 0
    d = r.report()["defense"]
    # why: the active clique pair clustered, and punishment bit too
    assert d["n_clusters"] >= 1
    assert set(d["clique_hosts_clustered"]) <= set(r.clique_host_ids())
    assert len(d["clique_hosts_clustered"]) >= 2
    assert d["quota_denials"] + d["clique_deferrals"] > 0


@scenario(ScenarioSpec(name="clique_small_fleet", seed=2, n_hosts=6,
                       clique=Clique(size=3), n_jobs=40))
def _check_clique_small_fleet(r):
    """3-of-6 clique — same story at half scale (seed-pinned golden)."""
    assert r.metrics.wrong_accepted == 4
    assert r.clique_quorum_wins() == 4
    assert r.server.counts()["jobs_success"] == 40


@scenario(ScenarioSpec(name="clique_small_fleet_defended", seed=2, n_hosts=6,
                       clique=Clique(size=3), n_jobs=40,
                       defense=DefensePolicy()))
def _check_clique_small_fleet_defended(r):
    """Honest negative result, pinned: at 6 hosts the defense does NOT
    beat the defense-off baseline (7 wrong vs 4). HR pinning fragments a
    tiny fleet into 2–3-host classes, and when a class is exactly the
    clique pair they only ever validate each other — the accomplice rule
    eventually clusters them (one partner never loses, so suspicion alone
    can't), but the early class-confined wins are already final. Work
    still completes (the HR relax sweep unpins stuck jobs) and the spread
    veto is live once the cluster forms. Pinned so the tiny-fleet HR
    hazard stays visible rather than averaged away."""
    assert r.metrics.wrong_accepted == 7
    assert r.clique_quorum_wins() == 7
    assert r.server.counts()["jobs_success"] == 40
    assert r.server.counts()["jobs_failure"] == 0
    d = r.report()["defense"]
    assert d["n_clusters"] >= 1
    assert d["spread_denials"] > 0  # the veto did engage post-clustering
    assert d["hr_relaxations"] > 0  # ...and the relax sweep kept work flowing


@scenario(ScenarioSpec(name="sybil_rejoin", seed=4, adaptive=True,
                       sybil=Sybil(), n_jobs=40, waves=8, wave_period=6 * HOUR))
def _check_sybil_rejoin(r):
    """Sybil churn-and-rejoin under adaptive replication: the fresh
    identity presents, gets work, and earns nothing (deep purge-path
    asserts live in test_sybil_rejoin_regression)."""
    new_id = sybil_identity_ids(r.spec)[0]
    assert new_id in r.sim.world.index
    assert any(i.host_id == new_id for i in r.server.store.instances.values())
    assert r.metrics.wrong_accepted == 0
    assert r.wrong_credit() == 0.0
    assert r.server.counts()["jobs_success"] == 40


@scenario(ScenarioSpec(name="sybil_serial", seed=4, adaptive=True, n_jobs=60,
                       horizon=3 * DAY, waves=12, wave_period=6 * HOUR,
                       sybil=Sybil(churn_at=0.5 * DAY, rejoin_at=0.75 * DAY,
                                   rejoins=3, period=0.5 * DAY)))
def _check_sybil_serial(r):
    """Serial Sybil: three fresh identities in sequence, each shedding the
    last one's (non-)reputation. Each gets work; none of them ever wins."""
    ids = sybil_identity_ids(r.spec)
    assert len(ids) == 3
    assert all(i in r.sim.world.index for i in ids)
    by_host = {i: 0 for i in ids}
    for inst in r.server.store.instances.values():
        if inst.host_id in by_host:
            by_host[inst.host_id] += 1
    assert all(n > 0 for n in by_host.values())
    assert r.metrics.wrong_accepted == 0
    assert r.credit_of_hosts(ids) == 0.0
    assert r.server.counts()["jobs_success"] == 60


@scenario(ScenarioSpec(name="credit_farm", seed=9, farm=CreditFarm(count=2, factor=8.0),
                       n_jobs=40, horizon=3 * DAY))
def _check_credit_farm(r):
    """Credit farmers inflate claimed PFC 8x while computing correctly.
    §7's claim normalization + outlier-robust granting means the inflation
    does NOT pay: per-farmer credit stays at/below the honest mean."""
    farm = r.farm_host_ids()
    assert len(farm) == 2
    per_farmer = r.credit_of_hosts(farm) / len(farm)
    honest = r.mean_honest_host_credit()
    assert 0.0 < per_farmer <= 1.5 * honest
    # the residual lie is still visible (claimed > granted on farmer
    # instances) but §7's host normalization has already absorbed most of
    # the 8x inflation before granting even sees it
    claimed = granted = 0.0
    for i in r.server.store.instances.values():
        if i.host_id in farm:
            claimed += i.claimed_credit
            granted += max(0.0, i.granted_credit)
    assert 1.3 * granted < claimed < 3.0 * granted
    assert r.metrics.wrong_accepted == 0
    assert r.server.counts()["jobs_success"] == 40


@scenario(ScenarioSpec(name="farm_adaptive", seed=9, adaptive=True,
                       farm=CreditFarm(count=3, factor=16.0), error_prob=0.01,
                       n_jobs=60, horizon=3 * DAY))
def _check_farm_adaptive(r):
    """16x farmers under adaptive replication on a mildly flaky fleet:
    still no payoff."""
    farm = r.farm_host_ids()
    assert len(farm) == 3
    per_farmer = r.credit_of_hosts(farm) / len(farm)
    assert 0.0 < per_farmer <= 1.5 * r.mean_honest_host_credit()
    assert r.metrics.wrong_accepted == 0
    assert r.server.counts()["jobs_success"] == 60


@scenario(ScenarioSpec(name="kitchen_sink", seed=10, trace=TraceReplay(n_timezones=3),
                       clique=Clique(size=3), farm=CreditFarm(count=2, factor=8.0),
                       correlated_failures=0.2, churn_rate=1.0 / (6 * DAY),
                       horizon=3 * DAY, n_jobs=60))
def _check_kitchen_sink(r):
    """Everything at once: trace waves + churn + correlated failures +
    clique + farmers. Work completes; adversarial leakage stays bounded."""
    counts = r.server.counts()
    assert counts["jobs_success"] == 60
    assert counts["jobs_failure"] == 0
    assert len(r.sim.specs) < r.spec.n_hosts  # churn happened
    assert r.metrics.wrong_accepted <= 4  # availability-starved clique wins a few
    assert r.clique_quorum_wins() == r.metrics.wrong_accepted
    assert r.wrong_credit() <= 2.0


# -- seed sweep: same spec shape, different seeds; golden bounds hold, and
#    the availability-starvation quorum defeat reproduces at every seed --

def _check_starved_clique(r):
    """Trace-driven availability + 3-host clique: replicas concentrate on
    whoever is online, so both copies of a job often land inside the
    always-cheating clique — quorum defeated without clique majority. The
    defense gap is pinned (exact counts are seed-golden, asserted identical
    across all three engines by the parity harness)."""
    assert r.server.counts()["jobs_success"] == 40
    assert r.server.counts()["jobs_failure"] == 0
    assert r.clique_quorum_wins() == r.metrics.wrong_accepted
    assert r.wrong_credit() > 0.0
    assert 2.0 <= r.metrics.replication_overhead <= 3.2


for _seed, _wins in ((7, 12), (11, 23)):
    @scenario(ScenarioSpec(name=f"starved_clique_seed{_seed}", seed=_seed,
                           trace=TraceReplay(n_timezones=3), clique=Clique(size=3),
                           horizon=3 * DAY, n_jobs=40))
    def _check(r, _wins=_wins):
        _check_starved_clique(r)
        assert r.metrics.wrong_accepted == _wins


# The flip for the availability-starved variant: same trace-driven specs
# with the defense ON. 12 and 23 defeated quorums both contain to 4 — the
# wins finalized before the clique's first loss turned any member
# suspicious (the structural residual; module docstring). Everything after
# the cluster forms is vetoed by the effective-quorum rule.
for _seed in (7, 11):
    @scenario(ScenarioSpec(name=f"starved_clique_seed{_seed}_defended",
                           seed=_seed, trace=TraceReplay(n_timezones=3),
                           clique=Clique(size=3), horizon=3 * DAY, n_jobs=40,
                           defense=DefensePolicy()))
    def _check_defended(r):
        assert r.metrics.wrong_accepted == 4
        assert r.clique_quorum_wins() == 4
        assert r.server.counts()["jobs_success"] == 40
        assert r.server.counts()["jobs_failure"] == 0
        assert 2.0 <= r.metrics.replication_overhead <= 3.2
        d = r.report()["defense"]
        assert d["n_clusters"] >= 1
        assert len(d["clique_hosts_clustered"]) >= 2


@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_scenario_matrix(name):
    spec, check = SCENARIOS[name]
    result = run_parity(spec)
    _REPORTS.append(result.report())
    check(result)


# ---------------------------------------------------------------------------
# §3.4's core claim, end to end (ported): adaptive replication cuts the
# overhead toward 1 while the accepted-error rate stays bounded.
# ---------------------------------------------------------------------------

def test_adaptive_vs_plain_replication():
    base = dict(n_jobs=360, n_hosts=20, horizon=6 * DAY, error_prob=0.005,
                waves=12)
    plain = run_parity(ScenarioSpec(name="waves_plain", **base))
    adaptive = run_parity(ScenarioSpec(name="waves_adaptive", adaptive=True, **base))
    _REPORTS.append(plain.report())
    _REPORTS.append(adaptive.report())
    assert plain.metrics.replication_overhead >= 2.0
    assert adaptive.metrics.replication_overhead < plain.metrics.replication_overhead
    assert adaptive.metrics.replication_overhead < 1.9
    assert adaptive.metrics.error_rate <= 0.02
    assert adaptive.metrics.correct_accepted >= 330


# ---------------------------------------------------------------------------
# satellite regressions
# ---------------------------------------------------------------------------

def test_clique_defense_regression():
    """Pin the quorum-defeat boundary (§3.4). A 3-of-12 always-cheating
    clique with matching payloads cannot beat min_quorum=2 + adaptive
    replication: cheaters never validate, so they never become reputable,
    so their jobs keep getting replicated onto honest hosts. But the
    defense is structural, not reputational — once the clique covers
    enough of the *online* fleet (half the hosts here; or a trace-starved
    fleet, see starved_clique_seed*), both replicas land inside it and
    matching wrong payloads win.

    Both sides of the boundary are pinned: defense OFF, a 6-of-12 clique
    holds at 9 defeated quorums / <=8 credit leaked (seed 2) — adaptive
    replication alone never closes this. Defense ON (DefensePolicy: §3.4
    work-spreading clusters + HR classes + host punishment), the same
    clique contains to exactly 1 — the single pre-signal win. The
    defended golden lives in clique_half_fleet_defended; here we pin the
    *gap* so neither side can silently drift."""
    safe = run_spec(ScenarioSpec(name="clique_triple_adaptive_reg", seed=2,
                                 adaptive=True, clique=Clique(size=3), n_jobs=40))
    assert safe.metrics.wrong_accepted == 0
    assert safe.clique_quorum_wins() == 0
    assert safe.credit_of_hosts(safe.clique_host_ids()) == 0.0
    # every clique result that reached validation was marked INVALID
    clique = set(safe.clique_host_ids())
    judged = [i for i in safe.server.store.instances.values()
              if i.host_id in clique
              and i.validate_state in (ValidateState.VALID, ValidateState.INVALID)]
    assert judged and all(i.validate_state == ValidateState.INVALID for i in judged)

    broken = run_spec(ScenarioSpec(name="clique_half_fleet_reg", seed=2,
                                   clique=Clique(size=6), n_jobs=40))
    assert broken.metrics.wrong_accepted == 9  # the vulnerability, pinned
    assert 0.0 < broken.wrong_credit() <= 8.0

    defended = run_spec(ScenarioSpec(name="clique_half_fleet_def_reg", seed=2,
                                     clique=Clique(size=6), n_jobs=40,
                                     defense=DefensePolicy()))
    assert defended.metrics.wrong_accepted == 1  # the fix, pinned
    assert defended.wrong_credit() < broken.wrong_credit()
    assert defended.server.counts()["jobs_success"] == 40


def test_sybil_rejoin_regression():
    """Satellite regression: churn a malicious host, rejoin it under a new
    host id. The purge paths must not leak the old identity, and the new
    identity must restart untrusted."""
    spec = ScenarioSpec(name="sybil_rejoin_reg", seed=4, adaptive=True,
                        sybil=Sybil(), n_jobs=40, waves=8,
                        wave_period=6 * HOUR)
    r = run_spec(spec)
    old_id = spec.sybil.host_index + 1  # make_population ids are 1-based
    new_id = sybil_identity_ids(spec)[0]
    assert new_id == SYBIL_ID_BASE + 1
    server, sim = r.server, r.sim

    # old identity fully purged server-side (server.remove_host paths)
    assert old_id not in server.store.hosts
    assert old_id not in server.estimator._host_versions
    assert all(server.adaptive.reputation(old_id, v.id) == 0
               for v in server.store.apps["w"].versions)
    assert all(h != old_id for h, _ in server.adaptive.consecutive_valid)
    assert old_id not in sim.specs and old_id not in sim.clients

    # ... but its world slot is tombstoned, never recycled: presenting the
    # same id again is impossible, which is what forces the Sybil to shed
    # its reputation along with its identity
    assert old_id in sim.world.index
    assert not sim.world.alive[sim.world.index[old_id]]

    # the fresh identity registered, got work, and restarted untrusted
    assert new_id in sim.specs and new_id in server.store.hosts
    new_instances = [i for i in server.store.instances.values()
                     if i.host_id == new_id]
    assert new_instances
    assert all(server.adaptive.reputation(new_id, v.id) == 0
               for v in server.store.apps["w"].versions)
    # always-cheating under quorum-2: every judged result INVALID, no credit
    judged = [i for i in new_instances
              if i.validate_state in (ValidateState.VALID, ValidateState.INVALID)]
    assert judged and all(i.validate_state == ValidateState.INVALID for i in judged)
    assert server.credit.total.get(f"host:{new_id}", 0.0) == 0.0
    assert r.metrics.wrong_accepted == 0

    # with the defense layer ON, the purge must be just as airtight: the
    # churned identity leaves no agreement stats, suspicion, cluster
    # membership, backoff, HR census entry, or live quota row behind
    rd = run_spec(ScenarioSpec(**{**vars(spec), "name": "sybil_rejoin_def_reg",
                                  "defense": DefensePolicy()}))
    d = rd.server.defense
    assert old_id not in d._lost and old_id not in d._validated
    assert old_id not in d._agree
    assert all(old_id not in peers for peers in d._agree.values())
    assert old_id not in d.clusters()
    assert old_id not in d._backoff
    assert old_id not in d._hr_of_host
    for table in (d.denied_quota_by, d.denied_spread_by, d.deferred_by,
                  d.cancelled_by):
        assert old_id not in table
    hr = d._host_idx.get(old_id)
    if hr is not None:  # dense slot stays mapped; row must be factory-fresh
        assert (d.quota[hr, :] == d.policy.quota_init).all()
        assert (d.sent[hr, :] == 0).all()
    # the fresh identity still gets work and still earns nothing
    assert any(i.host_id == new_id for i in rd.server.store.instances.values())
    assert rd.server.credit.total.get(f"host:{new_id}", 0.0) == 0.0
    assert rd.metrics.wrong_accepted == 0


# ---------------------------------------------------------------------------
# defense liveness: placement constraints never deadlock. HR pinning and
# the spread veto *restrict* eligible hosts, so the hazard is a job whose
# eligible set goes empty forever; the relax sweeps (hr_relaxations /
# spread_relaxations) must guarantee drain on any honest fleet.
# ---------------------------------------------------------------------------

def _assert_defense_drains(seed, n_hosts, n_jobs, error_prob, with_trace):
    spec = ScenarioSpec(
        name="defense_drain", seed=seed, n_hosts=n_hosts, n_jobs=n_jobs,
        error_prob=error_prob,
        trace=TraceReplay(n_timezones=2) if with_trace else None,
        horizon=3 * DAY if with_trace else 2 * DAY,
        defense=DefensePolicy(),
    )
    r = run_spec(spec)
    c = r.server.counts()
    assert c["jobs_success"] == n_jobs, c
    assert c["jobs_failure"] == 0, c
    assert c["instances_unsent"] == 0, c  # nothing wedged behind a pin
    assert c["instances_in_progress"] == 0, c
    assert r.metrics.wrong_accepted == 0
    if error_prob == 0.0:
        # clean fleets never cluster; flaky ones may false-cluster on
        # small samples (co-INVALID pairs), which costs only overhead —
        # the drain asserts above are what prove it stays harmless
        assert r.report()["defense"]["n_clusters"] == 0


@pytest.mark.parametrize(
    "seed,n_hosts,n_jobs,error_prob,with_trace",
    [
        (0, 4, 8, 0.0, False),     # tiny fleet: HR classes are 1-2 hosts
        (1, 6, 12, 0.1, False),    # flaky: retries stress the quota table
        (2, 12, 20, 0.05, False),
        (3, 12, 16, 0.05, True),   # diurnal starvation + flaky
        (4, 5, 10, 0.15, True),    # tiny AND starved AND very flaky
    ],
)
def test_defense_never_deadlocks_corners(seed, n_hosts, n_jobs, error_prob,
                                         with_trace):
    """Deterministic corner sweep of the liveness contract (always runs,
    even without hypothesis installed)."""
    _assert_defense_drains(seed, n_hosts, n_jobs, error_prob, with_trace)


def test_defense_never_deadlocks():
    """Property (hypothesis): with the full defense stack ON and an
    all-honest fleet, every job reaches quorum and the queue drains —
    across fleet sizes, error rates, and trace-driven availability."""
    pytest.importorskip("hypothesis")  # optional dep: see requirements-dev.txt
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=25, deadline=None)
    @given(
        seed=st.integers(min_value=0, max_value=10**6),
        n_hosts=st.integers(min_value=4, max_value=14),
        n_jobs=st.integers(min_value=4, max_value=20),
        error_prob=st.sampled_from([0.0, 0.02, 0.1]),
        with_trace=st.booleans(),
    )
    def prop(seed, n_hosts, n_jobs, error_prob, with_trace):
        _assert_defense_drains(seed, n_hosts, n_jobs, error_prob, with_trace)

    prop()


# ---------------------------------------------------------------------------
# generation purity: same (spec, seed) => identical populations, world
# columns, and event streams (hypothesis property, satellite 3)
# ---------------------------------------------------------------------------

import numpy as np  # noqa: E402

from repro.core.scenarios import build  # noqa: E402


def _spec_from(draw_seed, n_hosts, with_trace, with_clique, with_farm, with_sybil):
    return ScenarioSpec(
        name="prop", seed=draw_seed, n_hosts=n_hosts, n_jobs=8,
        trace=TraceReplay(n_timezones=2) if with_trace else None,
        clique=Clique(size=min(3, n_hosts - 1)) if with_clique else None,
        farm=CreditFarm(count=2) if with_farm else None,
        sybil=Sybil() if with_sybil else None,
        adaptive=with_sybil,
    )


def _pop_fields(pop):
    out = []
    for s in pop:
        d = dict(vars(s))
        h = d.pop("host")
        d["host"] = (h.id, h.platforms, h.cpu_vendor, h.cpu_model,
                     h.os_version, h.on_fraction, h.volunteer_id,
                     tuple((rt, r.ninstances, r.peak_flops, r.availability)
                           for rt, r in sorted(h.resources.items(),
                                               key=lambda kv: kv[0].value)))
        out.append(d)
    return out


def _assert_generation_pure(seed, n_hosts, with_trace, with_clique,
                            with_farm, with_sybil):
    spec = _spec_from(seed, n_hosts, with_trace, with_clique, with_farm,
                      with_sybil)
    # same spec twice: field-identical populations...
    assert _pop_fields(generate_population(spec)) == _pop_fields(
        generate_population(spec))
    # ...and identical constructed worlds: every HostArrays column and the
    # full pending event stream (heap entries are (t, seq, kind, host))
    _, sim_a, _ = build(spec)
    _, sim_b, _ = build(spec)
    wa, wb = sim_a.world, sim_b.world
    assert wa.index == wb.index
    for col in ("ids", "alive", "available", "flops", "cap_ncpu", "ram",
                "b_hi", "time_slice", "sched_ncpu"):
        assert np.array_equal(getattr(wa, col), getattr(wb, col)), col
    assert sorted(sim_a._heap) == sorted(sim_b._heap)
    # a different seed must actually move the population
    other = ScenarioSpec(**{**vars(spec), "seed": seed + 1})
    assert _pop_fields(generate_population(other)) != _pop_fields(
        generate_population(spec))


@pytest.mark.parametrize(
    "seed,n_hosts,with_trace,with_clique,with_farm,with_sybil",
    [
        (0, 4, False, False, False, False),
        (1, 12, True, False, False, False),
        (2, 12, False, True, False, False),
        (3, 12, False, False, True, False),
        (4, 12, False, False, False, True),
        (5, 8, True, True, True, False),
        (6, 14, True, True, True, True),
        (982451653, 5, True, False, True, True),
    ],
)
def test_generation_purity_corners(seed, n_hosts, with_trace, with_clique,
                                   with_farm, with_sybil):
    """Deterministic corner sweep of the purity contract (always runs,
    even without hypothesis installed)."""
    _assert_generation_pure(seed, n_hosts, with_trace, with_clique,
                            with_farm, with_sybil)


def test_generation_pure_in_spec_and_seed():
    """Property (hypothesis): scenario generation is a pure function of
    (spec, seed) across the whole layered spec space."""
    pytest.importorskip("hypothesis")  # optional dep: see requirements-dev.txt
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=25, deadline=None)
    @given(
        seed=st.integers(min_value=0, max_value=10**6),
        n_hosts=st.integers(min_value=4, max_value=14),
        with_trace=st.booleans(),
        with_clique=st.booleans(),
        with_farm=st.booleans(),
        with_sybil=st.booleans(),
    )
    def prop(seed, n_hosts, with_trace, with_clique, with_farm, with_sybil):
        _assert_generation_pure(seed, n_hosts, with_trace, with_clique,
                                with_farm, with_sybil)

    prop()


# ---------------------------------------------------------------------------
# full-scale adversarial run (CI: behind the slow marker)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_adversarial_10k_hosts():
    """10k-host fleet with a 500-host clique, 200 farmers, churn, and an
    epoch-batched vectorized world: the engines hold at population scale.
    Engine-only (the 3-axis parity contract is already pinned per-scenario
    above; a scalar-oracle run at 10k hosts is minutes, not seconds)."""
    spec = ScenarioSpec(
        name="adversarial_10k", seed=12, n_hosts=10_000, n_jobs=3000,
        horizon=0.5 * DAY, est_hours=0.05, clique=Clique(size=500),
        farm=CreditFarm(count=200, factor=8.0), churn_rate=1.0 / (30 * DAY),
        availability=0.9,
    )
    r = run_spec(spec, epoch=60.0)
    _REPORTS.append(r.report())
    counts = r.server.counts()
    assert counts["jobs_success"] >= 2900
    assert r.metrics.error_rate <= 0.01  # 5% clique: quorum holds at scale
    assert r.clique_quorum_wins() == r.metrics.wrong_accepted
    assert 2.0 <= r.metrics.replication_overhead <= 2.6
