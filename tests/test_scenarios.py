"""End-to-end scenario matrix (§9): deterministic-seed EmBOINC-style runs
across the deployment regimes the paper's reliability story targets — churn,
malicious hosts, heterogeneous fleets, adaptive replication, intermittent
availability, long-horizon quiescence — asserting golden bounds on
SimMetrics (error_rate, replication_overhead, idle_fraction) and that the
batch validation engine reproduces the scalar oracle's metrics exactly in
every scenario.

EmBOINC-style simulation studies (cf. Anderson & Fedak, "The Computational
and Storage Potential of Volunteer Computing") hinge on exactly these
replication-overhead and accepted-error metrics; this suite pins them.
"""
import pytest

from repro.core import (
    App,
    AppVersion,
    GridSimulation,
    Job,
    JobState,
    Platform,
    ProjectServer,
    default_cpu_plan_class,
    fuzzy_comparator,
    gpu_plan_class,
    make_population,
    next_id,
    reset_ids,
)

DAY = 86400.0


def build_server(batch_validate, adaptive=False, gpu=False, delay_bound=4 * 3600.0):
    server = ProjectServer(name="p", purge_delay=1e18, batch_validate=batch_validate)
    app = App(
        name="w",
        min_quorum=2,
        init_ninstances=2,
        delay_bound=delay_bound,
        adaptive_replication=adaptive,
        comparator=fuzzy_comparator(rtol=1e-6, atol=1e-9),
    )
    for osn in ("windows", "mac", "linux"):
        app.add_version(
            AppVersion(
                id=next_id("appver"),
                app_name="w",
                platform=Platform(osn, "x86_64"),
                version_num=1,
                plan_class=default_cpu_plan_class(),
            )
        )
        if gpu:
            app.add_version(
                AppVersion(
                    id=next_id("appver"),
                    app_name="w",
                    platform=Platform(osn, "x86_64"),
                    version_num=1,
                    plan_class=gpu_plan_class(),
                )
            )
    server.add_app(app)
    return server


def run_scenario(batch_validate, n_jobs=60, n_hosts=12, horizon=2 * DAY,
                 sim_seed=3, pop_seed=1, adaptive=False, gpu=False,
                 delay_bound=4 * 3600.0, est_hours=0.2, waves=1,
                 wave_period=6 * 3600.0, vector_world=True, epoch=0.0,
                 **pop_kw):
    reset_ids()
    server = build_server(batch_validate, adaptive=adaptive, gpu=gpu,
                          delay_bound=delay_bound)
    pop = make_population(n_hosts, seed=pop_seed, horizon=horizon, **pop_kw)
    sim = GridSimulation(server, pop, seed=sim_seed,
                         vector_world=vector_world, epoch=epoch)
    per_wave = n_jobs // waves

    def submit(now):
        for _ in range(per_wave):
            server.submit_job(
                Job(id=next_id("job"), app_name="w",
                    est_flop_count=est_hours * 3600 * 16.5e9),
                now,
            )

    if waves == 1:
        submit(0.0)
    else:
        for w in range(waves):
            sim.schedule_callback(w * wave_period, submit)
    m = sim.run(horizon)
    sim.audit_validation()
    return server, sim, m


def _instance_states(server):
    return {
        i: (x.validate_state, x.granted_credit)
        for i, x in server.store.instances.items()
    }


def assert_engine_oracle_identical(kw):
    """Every scenario's results must be identical across the engine/oracle
    axes: batch_validate on/off *and* vector_world on/off (the epoch-batched
    columnar world loop vs the scalar per-event oracle). Returns the
    full-engine run for golden-bound assertions."""
    srv_b, sim_b, m_b = run_scenario(True, **dict(kw))
    srv_s, sim_s, m_s = run_scenario(False, **dict(kw))
    assert vars(m_b) == vars(m_s), "engine diverged from scalar oracle"
    assert srv_b.counts() == srv_s.counts()
    assert srv_b.credit.total == srv_s.credit.total
    assert _instance_states(srv_b) == _instance_states(srv_s)
    # the vectorized world loop must reproduce the scalar event loop
    # bit-for-bit: SimMetrics, job states, granted credit (ISSUE 5)
    srv_w, sim_w, m_w = run_scenario(True, vector_world=False, **dict(kw))
    assert vars(m_b) == vars(m_w), "vector world diverged from scalar loop"
    assert srv_b.counts() == srv_w.counts()
    assert srv_b.credit.total == srv_w.credit.total
    assert _instance_states(srv_b) == _instance_states(srv_w)
    assert {j: x.state for j, x in srv_b.store.jobs.items()} == {
        j: x.state for j, x in srv_w.store.jobs.items()
    }
    return srv_b, sim_b, m_b


class TestScenarioMatrix:
    def test_long_horizon_quiescence(self):
        """Clean dedicated grid, generous horizon: everything validates,
        nothing is wrongly accepted, and the plant goes quiescent —
        overhead settles at the quorum-2 floor and the tail of the horizon
        is idle."""
        server, sim, m = assert_engine_oracle_identical(
            dict(horizon=3 * DAY)
        )
        counts = server.counts()
        assert counts["jobs_success"] == 60
        assert counts["jobs_failure"] == 0
        assert m.error_rate == 0.0
        assert 2.0 <= m.replication_overhead <= 2.3
        # quiescent tail: instances all resolved, most capacity unused
        assert counts["instances_in_progress"] == 0
        assert counts["instances_unsent"] == 0
        assert m.idle_fraction > 0.5

    def test_high_churn(self):
        """Hosts permanently depart mid-run (§4): deadlines fire, retries
        land on surviving hosts, and the work still completes — at a
        visible replication-overhead premium."""
        server, sim, m = assert_engine_oracle_identical(
            dict(
                n_hosts=16,
                churn_rate=1.0 / (1.5 * DAY),
                horizon=5 * DAY,
                delay_bound=8 * 3600.0,
                est_hours=1.5,
            )
        )
        counts = server.counts()
        assert counts["jobs_success"] >= 56  # work survives departures
        assert m.error_rate == 0.0
        assert 2.0 <= m.replication_overhead <= 2.5
        # churn actually happened and cost something: most hosts gone,
        # deadline misses retried elsewhere
        assert len(sim.specs) < 8
        assert sum(t.metrics.timeouts for t in server.transitioners) > 0

    def test_malicious_hosts(self):
        """5% malicious volunteers (§3.4): quorum-2 replication rejects
        every fabricated result."""
        server, sim, m = assert_engine_oracle_identical(
            dict(malicious_fraction=0.05, error_prob=0.01, horizon=3 * DAY)
        )
        counts = server.counts()
        assert m.wrong_accepted == 0
        assert m.error_rate == 0.0
        assert counts["jobs_success"] >= 55
        # corruption forced extra (tie-breaker) instances beyond the quorum
        assert m.replication_overhead > 2.0

    def test_heterogeneous_cpu_gpu_mix(self):
        """Half the fleet carries a GPU ~60x the CPU speed (§3.1 plan
        classes): the mixed fleet validates cross-device via the fuzzy
        comparator and finishes much faster than CPU-only."""
        server, sim, m = assert_engine_oracle_identical(
            dict(gpu=True, gpu_fraction=0.5, horizon=2 * DAY, n_jobs=80,
                 est_hours=0.4)
        )
        counts = server.counts()
        assert counts["jobs_success"] == 80
        assert m.error_rate == 0.0
        # GPU instances actually dispatched: some PFC came from GPU hosts
        gpu_versions = {
            v.id
            for v in server.store.apps["w"].versions
            if v.plan_class.name.startswith("gpu")
        }
        assert any(
            i.app_version_id in gpu_versions
            for i in server.store.instances.values()
        )

    def test_adaptive_vs_plain_replication(self):
        """§3.4's core claim, end to end: adaptive replication cuts the
        overhead toward 1 while the accepted-error rate stays bounded."""
        kw = dict(n_jobs=360, n_hosts=20, horizon=6 * DAY, error_prob=0.005,
                  waves=12)
        _, _, plain = assert_engine_oracle_identical(dict(kw))
        _, _, adaptive = assert_engine_oracle_identical(dict(kw, adaptive=True))
        assert plain.replication_overhead >= 2.0
        assert adaptive.replication_overhead < plain.replication_overhead
        assert adaptive.replication_overhead < 1.9
        assert adaptive.error_rate <= 0.02
        assert adaptive.correct_accepted >= 330

    def test_low_availability(self):
        """Hosts compute only ~60% of the time (§1.1): throughput drops
        but correctness and eventual completion hold, and the measured
        idle fraction reflects the unavailability."""
        server, sim, m = assert_engine_oracle_identical(
            dict(availability=0.6, horizon=4 * DAY)
        )
        counts = server.counts()
        assert counts["jobs_success"] >= 55
        assert m.error_rate == 0.0
        assert m.idle_fraction >= 0.35

    def test_error_prone_fleet(self):
        """Flaky hardware corrupting 5% of results: replication filters
        every corruption; the overhead premium pays for it."""
        server, sim, m = assert_engine_oracle_identical(
            dict(error_prob=0.05, horizon=3 * DAY)
        )
        assert m.wrong_accepted == 0
        assert server.counts()["jobs_success"] >= 55
        assert m.replication_overhead > 2.0
        # invalid results actually flowed through the validator
        from repro.core import ValidateState

        assert any(
            i.validate_state == ValidateState.INVALID
            for i in server.store.instances.values()
        )
