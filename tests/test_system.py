"""End-to-end behaviour tests: the EmBOINC simulator driving real
server+client code, and the volunteer-grid trainer with injected faults."""
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.core import (
    App,
    AppVersion,
    GridSimulation,
    Job,
    JobState,
    Platform,
    ProjectServer,
    default_cpu_plan_class,
    fuzzy_comparator,
    make_population,
    next_id,
    reset_ids,
)
from repro.data import DataConfig
from repro.optim import AdamWConfig
from repro.runtime import GridTrainer


def build_sim(n_jobs=60, n_hosts=12, adaptive=False, error_prob=0.0,
              malicious_fraction=0.0, availability=1.0, churn_rate=0.0,
              horizon=2 * 86400.0, delay_bound=4 * 3600.0, seed=3):
    reset_ids()
    server = ProjectServer(name="p", purge_delay=1e18)
    app = App(
        name="w",
        min_quorum=2,
        init_ninstances=2,
        delay_bound=delay_bound,
        adaptive_replication=adaptive,
        comparator=fuzzy_comparator(rtol=1e-6, atol=1e-9),
    )
    for osn in ("windows", "mac", "linux"):
        app.add_version(
            AppVersion(
                id=next_id("appver"),
                app_name="w",
                platform=Platform(osn, "x86_64"),
                version_num=1,
                plan_class=default_cpu_plan_class(),
            )
        )
    server.add_app(app)
    for _ in range(n_jobs):
        server.submit_job(Job(id=next_id("job"), app_name="w",
                              est_flop_count=0.2 * 3600 * 16.5e9))
    pop = make_population(
        n_hosts, seed=1, availability=availability, error_prob=error_prob,
        malicious_fraction=malicious_fraction, churn_rate=churn_rate, horizon=horizon,
    )
    sim = GridSimulation(server, pop, seed=seed)
    return server, sim


class TestSimulation:
    def test_all_jobs_complete_in_clean_grid(self):
        server, sim = build_sim()
        m = sim.run(2 * 86400.0)
        sim.audit_validation()
        counts = server.counts()
        assert counts["jobs_success"] == 60
        assert m.wrong_accepted == 0

    def test_corruption_never_accepted_with_full_replication(self):
        server, sim = build_sim(error_prob=0.05, malicious_fraction=0.2)
        m = sim.run(3 * 86400.0)
        sim.audit_validation()
        assert m.wrong_accepted == 0  # quorum-of-2 catches all corruption
        assert server.counts()["jobs_success"] >= 50

    def test_churn_jobs_retried_elsewhere(self):
        server, sim = build_sim(
            n_hosts=16, churn_rate=1.0 / (1.0 * 86400.0), horizon=4 * 86400.0,
            delay_bound=2 * 3600.0,
        )
        sim.run(4 * 86400.0)
        sim.audit_validation()
        counts = server.counts()
        # work survives departures: the vast majority completes
        assert counts["jobs_success"] >= 54

    def test_availability_interruption_resumes(self):
        server, sim = build_sim(availability=0.6, horizon=4 * 86400.0)
        sim.run(4 * 86400.0)
        assert server.counts()["jobs_success"] >= 55

    def test_credit_granted_to_valid_instances(self):
        server, sim = build_sim(n_jobs=30)
        sim.run(2 * 86400.0)
        total = sum(v for k, v in server.credit.total.items() if k.startswith("host:"))
        assert total > 0.0


class TestGridTrainer:
    def test_trains_through_faults(self):
        reset_ids()
        cfg = get_smoke_config("qwen3-0.6b").scaled(n_layers=2, d_model=64)
        dc = DataConfig(vocab=cfg.vocab, seq_len=64, batch_size=4, n_shards=2, seed=3)
        oc = AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=30)
        gt = GridTrainer(
            cfg, dc, oc, n_steps=8, n_hosts=8, seed=0,
            adaptive_replication=True, error_prob=0.05, malicious_fraction=0.15,
            availability=0.9,
        )
        r = gt.run()
        assert r.steps_completed == 8
        assert r.final_loss < r.losses[0]
        assert r.metrics.wrong_accepted == 0, "corrupted gradient accepted!"
        assert r.credit_total  # FLOPs ledger populated

    def test_deterministic_data_makes_replicas_comparable(self):
        reset_ids()
        cfg = get_smoke_config("mamba2-130m").scaled(n_layers=2, d_model=32)
        dc = DataConfig(vocab=cfg.vocab, seq_len=32, batch_size=2, n_shards=1, seed=7)
        oc = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=10)
        gt = GridTrainer(cfg, dc, oc, n_steps=4, n_hosts=6, seed=1,
                         adaptive_replication=False, min_quorum=2)
        r = gt.run()
        assert r.steps_completed == 4
        # with quorum-2 on every job, every accepted gradient was replicated
        assert r.metrics.instances_executed >= 2 * 4
