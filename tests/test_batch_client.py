"""Batch/scalar client-engine parity (§6.1–6.2, §9).

``BatchClientEngine`` is specified to be *bit-exact* with the scalar
oracle: same deadline-miss sets, same shortfall/idle/queue-duration/
saturation floats, same run sets (content, order, and applied state
transitions), and same work requests. These tests build twin client
populations — feature-dense: GPUs, multiple projects with debited REC
balances, RAM caps, preempted/running states, non-CPU-intensive jobs,
infinite remaining estimates — drive one through the scalar path and one
through the engine, and compare exhaustively. Simulator-level tests assert
that a ``batch_clients=True`` simulation is *identical* (metrics, client
queues, REC accounting) to the scalar per-host path.
"""
import random

import pytest

from repro.core import (
    App,
    AppVersion,
    BatchClientEngine,
    Job,
    Platform,
    ProjectServer,
    ResourceType,
    default_cpu_plan_class,
    next_id,
    reset_ids,
)
from repro.core.client import (
    Client,
    ClientJob,
    ClientPrefs,
    ClientResource,
    ProjectAttachment,
    RunState,
    wrr_simulate,
)
from repro.core.simulator import GridSimulation, make_population

CPU, GPU = ResourceType.CPU, ResourceType.GPU


def make_clients(n, seed, max_jobs=12, allow_inf=True):
    """Feature-dense random population: heterogeneous resources, two
    projects with unequal shares and debited balances, mixed job states,
    RAM-heavy working sets, GPU jobs, non-CPU-intensive jobs, and (when
    ``allow_inf``) jobs with est_flops == 0 (infinite remaining)."""
    rng = random.Random(seed)
    clients = []
    for h in range(n):
        res = {CPU: ClientResource(CPU, rng.choice([1, 2, 4, 8]), rng.uniform(1e9, 4e10))}
        if rng.random() < 0.4:
            res[GPU] = ClientResource(GPU, rng.choice([1, 2]), 1e12)
        c = Client(
            host_id=h + 1,
            resources=res,
            prefs=ClientPrefs(
                buffer_lo_days=rng.choice([0.02, 0.1]),
                buffer_hi_days=rng.choice([0.1, 0.5]),
            ),
            ram_bytes=rng.choice([1e9, 4e9, 8e9]),
        )
        c.attach(ProjectAttachment(name="p", resource_share=100.0))
        if rng.random() < 0.5:
            c.attach(ProjectAttachment(name="q", resource_share=rng.choice([50.0, 300.0])))
            if rng.random() < 0.5:
                c.rec.debit("p", rng.uniform(0, 1e5), 0.0)
        flops_choices = [1e9, 2e10] + ([0.0] if allow_inf else [])
        for i in range(rng.randrange(0, max_jobs)):
            usage = {CPU: rng.choice([0.5, 1.0, 2.0])}
            if GPU in res and rng.random() < 0.4:
                usage[GPU] = 1.0
            proj = "q" if ("q" in c.projects and rng.random() < 0.5) else "p"
            c.jobs.append(ClientJob(
                instance_id=h * 1000 + i,
                job_id=h * 1000 + i,
                project=proj,
                app_name="a",
                usage=usage,
                est_flops=rng.choice(flops_choices),
                est_flop_count=rng.uniform(1e11, 5e13),
                deadline=rng.uniform(0.0, 2 * 86400.0),
                est_wss=rng.choice([0.0, 0.5e9, 2e9]),
                fraction_done=rng.choice([0.0, 0.3, 0.99]),
                fraction_done_exact=rng.random() < 0.3,
                runtime=rng.uniform(0, 3600),
                state=rng.choice([
                    RunState.UNSTARTED, RunState.RUNNING,
                    RunState.PREEMPTED, RunState.DONE,
                ]),
                slice_start=rng.uniform(0, 1000),
                checkpoint_time=rng.uniform(0, 1000),
                non_cpu_intensive=rng.random() < 0.1,
            ))
        clients.append(c)
    return clients


def _assert_wrr_equal(sa, sb, host_id):
    assert sa.deadline_misses == sb.deadline_misses, host_id
    assert sa.shortfall == sb.shortfall, host_id
    assert sa.idle_instances == sb.idle_instances, host_id
    assert sa.queue_dur == sb.queue_dur, host_id
    assert sa.saturated_until == sb.saturated_until, host_id


@pytest.mark.parametrize("seed", range(4))
def test_wrr_batch_matches_scalar(seed):
    """Engine WRR pass == per-host wrr_simulate: identical miss id lists and
    exact float equality on every per-resource output."""
    now = 500.0
    A = make_clients(120, seed, allow_inf=False)
    B = make_clients(120, seed, allow_inf=False)
    sims_b = BatchClientEngine().wrr_batch(B, now)
    for c, sb in zip(A, sims_b):
        queued = [j for j in c.jobs if j.state != RunState.DONE]
        prio = c.project_priorities(now)
        sa = wrr_simulate(queued, c.resources, prio, c.prefs, now, c.ram_bytes)
        _assert_wrr_equal(sa, sb, c.host_id)


@pytest.mark.parametrize("seed", range(4))
def test_schedule_batch_matches_scalar(seed):
    """Engine run-set selection == Client.schedule: same chosen jobs in the
    same order, same run/preempt transitions, same slice_start stamps, and
    same deadline-miss flags across the whole queue."""
    now = 500.0
    A = make_clients(120, seed + 50, allow_inf=False)
    B = make_clients(120, seed + 50, allow_inf=False)
    runs_a = [c.schedule(now) for c in A]
    runs_b = BatchClientEngine().schedule_batch(B, now)
    for ca, cb, ra, rb in zip(A, B, runs_a, runs_b):
        sig = lambda js: [(j.instance_id, j.state, j.slice_start, j.deadline_miss) for j in js]  # noqa: E731
        assert sig(ra) == sig(rb), ca.host_id
        assert sig(ca.jobs) == sig(cb.jobs), ca.host_id
        assert sig(ca.running) == sig(cb.running), ca.host_id


@pytest.mark.parametrize("seed", range(4))
def test_needs_and_fetch_match_scalar(seed):
    """Work requests (shortfall/idle/queue-dur floats) and fetch-project
    decisions identical between engine and scalar path."""
    now = 500.0
    A = make_clients(120, seed + 100, allow_inf=False)
    B = make_clients(120, seed + 100, allow_inf=False)
    eng = BatchClientEngine()
    needs_b = eng.needs_work_batch(B, now)
    for ca, nb in zip(A, needs_b):
        assert ca.needs_work(now) == nb, ca.host_id
    A2 = make_clients(80, seed + 150, allow_inf=False)
    B2 = make_clients(80, seed + 150, allow_inf=False)
    fetch_b = BatchClientEngine().choose_fetch_batch(B2, now)
    for ca, fb in zip(A2, fetch_b):
        fa = ca.choose_fetch_project(now)
        assert (fa is None) == (fb is None), ca.host_id
        if fa is not None:
            assert fa.project == fb.project and fa.requests == fb.requests


def test_tick_batch_matches_sequential_tick():
    """tick_batch (one fused WRR pass shared by reschedule + work fetch)
    == scalar schedule() followed by needs_work()."""
    now = 1234.0
    A = make_clients(100, 7, allow_inf=False)
    B = make_clients(100, 7, allow_inf=False)
    runs_b, needs_b = BatchClientEngine().tick_batch(B, now)
    for ca, rb, nb in zip(A, runs_b, needs_b):
        ra = ca.schedule(now)
        na = ca.needs_work(now)
        assert [(j.instance_id, j.state) for j in ra] == [
            (j.instance_id, j.state) for j in rb
        ]
        assert na == nb


def test_parity_with_infinite_estimates():
    """Jobs with est_flops == 0 have infinite remaining estimates — the
    scalar oracle spins its event cap through inf/NaN arithmetic, and the
    engine must reproduce its Python min/max NaN semantics exactly (small
    population: the degenerate spin costs 10k events per host)."""
    now = 500.0
    A = make_clients(12, 31, max_jobs=6, allow_inf=True)
    B = make_clients(12, 31, max_jobs=6, allow_inf=True)
    eng = BatchClientEngine()
    sims_b = eng.wrr_batch(B, now)
    for c, sb in zip(A, sims_b):
        queued = [j for j in c.jobs if j.state != RunState.DONE]
        prio = c.project_priorities(now)
        sa = wrr_simulate(queued, c.resources, prio, c.prefs, now, c.ram_bytes)
        _assert_wrr_equal(sa, sb, c.host_id)
    runs_a = [c.schedule(now) for c in A]
    runs_b = BatchClientEngine().schedule_batch(B, now)
    for ca, ra, rb in zip(A, runs_a, runs_b):
        assert [j.instance_id for j in ra] == [j.instance_id for j in rb]


def test_schedule_batch_empty_queue_accrual_parity():
    """Client.schedule early-returns *before* the REC priority accrual on an
    empty queue; schedule_batch must mirror that (an accrual at an
    intermediate time changes float association and can diverge balances),
    while needs_work accrues unconditionally on both paths."""
    def mk():
        c = Client(host_id=1, resources={CPU: ClientResource(CPU, 2, 1e9)})
        c.attach(ProjectAttachment(name="p"))
        return c

    a, b = mk(), mk()
    a.schedule(0.8)
    BatchClientEngine().schedule_batch([b], 0.8)
    assert a.rec.accounts["p"].last_update == b.rec.accounts["p"].last_update
    assert a.project_priorities(600.9) == b.project_priorities(600.9)

    a2, b2 = mk(), mk()
    a2.needs_work(0.8)
    BatchClientEngine().needs_work_batch([b2], 0.8)
    assert a2.rec.accounts["p"].last_update == b2.rec.accounts["p"].last_update
    assert a2.project_priorities(600.9) == b2.project_priorities(600.9)


def test_engine_edge_cases():
    """Empty populations, empty queues, all-DONE queues, GPU-only jobs on a
    CPU-only host, and the non-CPU-intensive override."""
    eng = BatchClientEngine()
    assert eng.wrr_batch([], 0.0) == []
    assert eng.schedule_batch([], 0.0) == []

    c = Client(host_id=1, resources={CPU: ClientResource(CPU, 2, 1e9)})
    c.attach(ProjectAttachment(name="p"))
    # all-DONE queue behaves like an empty one
    done = ClientJob(instance_id=1, job_id=1, project="p", app_name="a",
                     usage={CPU: 1.0}, est_flops=1e9, est_flop_count=1e12,
                     deadline=1e9, state=RunState.DONE)
    gpu_only = ClientJob(instance_id=2, job_id=2, project="p", app_name="a",
                         usage={GPU: 1.0}, est_flops=1e9, est_flop_count=1e12,
                         deadline=1e9)
    nci = ClientJob(instance_id=3, job_id=3, project="p", app_name="a",
                    usage={CPU: 4.0}, est_flops=1e9, est_flop_count=1e12,
                    deadline=1e9, non_cpu_intensive=True)
    c.jobs = [done, gpu_only, nci]
    twin = Client(host_id=1, resources={CPU: ClientResource(CPU, 2, 1e9)})
    twin.attach(ProjectAttachment(name="p"))
    import copy
    twin.jobs = copy.deepcopy(c.jobs)

    (run_b,), (needs_b,) = eng.tick_batch([c], 0.0)
    run_a = twin.schedule(0.0)
    needs_a = twin.needs_work(0.0)
    assert [j.instance_id for j in run_a] == [j.instance_id for j in run_b]
    # the non-CPU-intensive job always runs (§3.5); the GPU job can't
    assert [j.instance_id for j in run_b] == [3]
    assert needs_a == needs_b


def test_property_wrr_parity_random_queues():
    """Property (hypothesis): scalar wrr_simulate and the batched engine
    agree on miss sets and shortfalls across random queues."""
    pytest.importorskip("hypothesis")  # optional dep: see requirements-dev.txt
    from hypothesis import given, settings, strategies as st

    job_st = st.tuples(
        st.floats(min_value=0.0, max_value=2e10),   # est_flops (0 => inf rem)
        st.floats(min_value=1e9, max_value=5e13),   # est_flop_count
        st.floats(min_value=0.0, max_value=1.0),    # fraction_done
        st.booleans(),                              # fraction_done_exact
        st.floats(min_value=0.0, max_value=7200.0),  # runtime
        st.floats(min_value=0.0, max_value=2e5),    # deadline
        st.sampled_from([0.5, 1.0, 2.0]),           # cpu usage
        st.booleans(),                              # uses gpu
        st.sampled_from([0.0, 0.5e9, 2e9]),         # est_wss
        st.sampled_from([RunState.UNSTARTED, RunState.RUNNING, RunState.DONE]),
    )
    host_st = st.tuples(
        st.lists(job_st, max_size=8),
        st.integers(min_value=1, max_value=8),      # ncpus
        st.booleans(),                              # has gpu resource
        st.sampled_from([1e9, 8e9]),                # ram
    )

    @settings(max_examples=60, deadline=None)
    @given(st.lists(host_st, max_size=12))
    def check(hosts):
        def build():
            out = []
            for h, (jobs, ncpus, has_gpu, ram) in enumerate(hosts):
                res = {CPU: ClientResource(CPU, ncpus, 1e9)}
                if has_gpu:
                    res[GPU] = ClientResource(GPU, 1, 1e12)
                c = Client(host_id=h + 1, resources=res, ram_bytes=ram)
                c.attach(ProjectAttachment(name="p"))
                for i, (ef, efc, fd, ex, rt, dl, cu, ug, wss, state) in enumerate(jobs):
                    usage = {CPU: cu}
                    if ug:
                        usage[GPU] = 1.0
                    c.jobs.append(ClientJob(
                        instance_id=h * 100 + i, job_id=h * 100 + i,
                        project="p", app_name="a", usage=usage,
                        est_flops=ef, est_flop_count=efc, deadline=dl,
                        est_wss=wss, fraction_done=fd,
                        fraction_done_exact=ex, runtime=rt, state=state,
                    ))
                out.append(c)
            return out

        A, B = build(), build()
        sims_b = BatchClientEngine().wrr_batch(B, 100.0)
        for c, sb in zip(A, sims_b):
            queued = [j for j in c.jobs if j.state != RunState.DONE]
            prio = c.project_priorities(100.0)
            sa = wrr_simulate(queued, c.resources, prio, c.prefs, 100.0, c.ram_bytes)
            assert set(sa.deadline_misses) == set(sb.deadline_misses)
            assert sa.shortfall == sb.shortfall
            assert sa.idle_instances == sb.idle_instances

    check()


# ---------------------------------------------------------------------------
# simulator integration
# ---------------------------------------------------------------------------


def _sim(batch_clients, n_hosts=24, n_jobs=80, seed=4, **pop_kw):
    reset_ids()
    server = ProjectServer(name="p", cache_size=64)
    app = App(name="work", min_quorum=1, init_ninstances=1, delay_bound=6 * 3600.0)
    for osn in ("windows", "mac", "linux"):
        app.add_version(
            AppVersion(
                id=next_id("appver"),
                app_name="work",
                platform=Platform(osn, "x86_64"),
                version_num=1,
                plan_class=default_cpu_plan_class(),
            )
        )
    server.add_app(app)
    for i in range(n_jobs):
        server.submit_job(
            Job(id=next_id("job"), app_name="work", est_flop_count=1e12), 0.0
        )
    pop = make_population(n_hosts, seed=seed, **pop_kw)
    # vector_world=False: these are the PR 3 batch_clients on/off parity
    # twins — the vectorized world loop supersedes the flag, so it must be
    # off for the scalar-client oracle to actually run (the vector loop has
    # its own parity matrix in tests/test_world.py)
    return GridSimulation(server, pop, seed=seed, batch_clients=batch_clients,
                          vector_world=False)


def _client_sig(sim):
    out = {}
    for hid, c in sorted(sim.clients.items()):
        out[hid] = (
            sorted((j.instance_id, j.state, j.deadline_miss) for j in c.jobs),
            sorted(j.instance_id for j in c.completed),
            {n: a.total_used for n, a in c.rec.accounts.items()},
        )
    return out


def test_simulator_rpc_batch_with_batch_clients():
    """Driving _handle_rpc_batch with the client engine on must leave the
    server store and every client's queue identical to the scalar path."""
    sim_a = _sim(False)
    sim_b = _sim(True)
    ids = list(sim_a.clients.keys())
    sim_a._handle_rpc_batch(ids, 0.0)
    sim_b._handle_rpc_batch(ids, 0.0)
    assert _client_sig(sim_a) == _client_sig(sim_b)
    assert sim_a.metrics.rpcs == sim_b.metrics.rpcs
    assert sim_a.metrics.rpcs_with_work == sim_b.metrics.rpcs_with_work


def test_simulator_completion_batching():
    """_handle_completions_batch == per-host _handle_completions at the same
    virtual time (completion marking, batched reschedule, report RPCs)."""
    sim_a = _sim(False, seed=9)
    sim_b = _sim(True, seed=9)
    ids = list(sim_a.clients.keys())
    sim_a._handle_rpc_batch(ids, 0.0)
    sim_b._handle_rpc_batch(ids, 0.0)
    # fast-forward every running job to completion at a shared tick
    for sim in (sim_a, sim_b):
        for running in sim.running.values():
            for rj in running.values():
                rj.accrued = rj.actual_total
    t = 3600.0
    for hid in ids:
        sim_a._handle_completions(hid, t)
    sim_b._handle_completions_batch(ids, t)
    assert _client_sig(sim_a) == _client_sig(sim_b)
    assert sim_a.metrics.instances_executed == sim_b.metrics.instances_executed
    assert sim_a.metrics.rpcs == sim_b.metrics.rpcs


def test_whole_simulation_metrics_parity_500_hosts():
    """Acceptance: end-of-run simulation metrics identical between the
    scalar client path and the batched engine at a 500-host population."""
    n_jobs = 1200
    sim_a = _sim(False, n_hosts=500, n_jobs=n_jobs, gpu_fraction=0.25,
                 availability=0.9)
    sim_b = _sim(True, n_hosts=500, n_jobs=n_jobs, gpu_fraction=0.25,
                 availability=0.9)
    ma = sim_a.run(6 * 3600.0)
    mb = sim_b.run(6 * 3600.0)
    sim_a.audit_validation()
    sim_b.audit_validation()
    assert ma == mb
    assert _client_sig(sim_a) == _client_sig(sim_b)


def test_simulation_to_completion_with_batch_clients():
    """A batch-client simulation still drives every job to completion and
    REC debits accrue (the §6.1 accounting fix)."""
    sim = _sim(True, n_hosts=16, n_jobs=60)
    metrics = sim.run(12 * 3600.0)
    assert metrics.instances_executed == 60
    assert len(sim.server.assimilated_outputs) == 60
    total_used = sum(
        a.total_used for c in sim.clients.values() for a in c.rec.accounts.values()
    )
    assert total_used > 0.0


def test_world_snapshot_matches_object_snapshot():
    """ISSUE 5: the engine's world-backed snapshot (persistent columns,
    gathered per batch) must be field-for-field bit-identical to the
    object-materialized snapshot over the same queues — and therefore
    produce identical WRR outputs and work requests."""
    import numpy as np

    sim = _sim(True, n_hosts=32, n_jobs=160, seed=9)
    sim.run(5400.0)
    world = sim.world
    hids = [h for h in sim.specs if world.is_available(h)]
    assert hids
    engine = sim.client_engine
    now = sim.now + 30.0
    # column -> object sync so the object path sees the authoritative
    # accrual state the world columns carry
    world.sync_objects(hids)
    sw = engine._snapshot_world(world, hids, now)
    so = engine._snapshot([sim.clients[h] for h in hids], now)
    assert sw.H == so.H and sw.J == so.J
    assert sw.identity_perm and so.identity_perm
    np.testing.assert_array_equal(sw.live, so.live)
    for name in ("rem", "dl", "wss", "slice_start", "chk_time", "prio_j",
                 "run_state", "nci", "cu"):
        np.testing.assert_array_equal(
            getattr(sw, name), getattr(so, name), err_msg=name
        )
    for rt in so.rtypes:
        np.testing.assert_array_equal(sw.usage[rt], so.usage[rt], err_msg=str(rt))
        np.testing.assert_array_equal(sw.nins[rt], so.nins[rt])
        np.testing.assert_array_equal(sw.has[rt], so.has[rt])
    for name in ("ram", "ram_frac", "horizon", "ts", "ncpu"):
        np.testing.assert_array_equal(getattr(sw, name), getattr(so, name))
    assert [[j.instance_id for j in q] for q in sw.queued] == [
        [j.instance_id for j in q] for q in so.queued
    ]
    # and the derived outputs coincide exactly
    needs_w = engine._needs_from_raw(sw, engine._wrr_raw(sw, now))
    needs_o = engine._needs_from_raw(so, engine._wrr_raw(so, now))
    assert needs_w == needs_o
