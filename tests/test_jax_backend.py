"""NumPy ⇄ JAX backend parity (the 4th test-matrix axis, kernel-level).

The scenario matrix locks the JAX backend down end-to-end; these tests
attack the same contract from below with randomized inputs:

- property tests over the dispatch score/estimate kernels and the
  eligibility scan, against inline NumPy replicas of the engine's exact
  IEEE op order;
- fleet-level WRR / run-set identity between ``BatchClientEngine()`` and
  ``BatchClientEngine(backend="jax")`` on feature-dense random fleets;
- digest-bucket equality between the Pallas ``quorum_compare`` grouping
  and a ``quorum_compare_ref``-based greedy grouping across tolerance
  bands, including the -0.0 and NaN payload corners pinned in PR 4;
- dirty-upload regression: mutate hosts through every ``_touch`` hook
  between device ticks and assert the incrementally-uploaded device
  columns equal the host arrays (i.e. match a from-scratch upload), and
  that a NumPy twin world stays bitwise identical.

Each property is a function of one integer seed. A seeded sweep always
runs; when hypothesis is installed (requirements-dev.txt) the same
properties also run under its shrinking search.
"""
import random

import numpy as np
import pytest

jax = pytest.importorskip("jax")

try:  # optional dep: see requirements-dev.txt
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core import BatchClientEngine, ResourceType
from repro.core.client import (
    Client,
    ClientJob,
    ClientPrefs,
    ClientResource,
    ProjectAttachment,
    RunState,
)
from repro.core.jax_backend import (
    HAVE_JAX,
    dispatch_elig,
    dispatch_scores,
    quorum_group_codes,
    resolve_backend,
)
from repro.core.scheduler import W_BALANCE, W_KEYWORD, W_PRIORITY, W_SKIPPED
from repro.core.world import HostArrays
from repro.kernels.quorum_compare.ref import quorum_compare_ref
from test_batch_client import _assert_wrr_equal, make_clients

CPU = ResourceType.CPU

assert HAVE_JAX  # importorskip above guarantees it


def hyp(prop, **kw):
    """Attach a hypothesis seed-search twin of a seeded property test."""

    def deco(fn):
        if not HAVE_HYPOTHESIS:
            return None  # seeded sweep still covers the property
        return settings(deadline=None, **kw)(
            given(st.integers(0, 2**31 - 1))(fn)
        )

    return deco(prop)


def test_resolve_backend():
    assert resolve_backend("numpy") == "numpy"
    assert resolve_backend("jax") == "jax"
    with pytest.raises(ValueError):
        resolve_backend("torch")


# ---------------------------------------------------------------------------
# dispatch kernels vs inline NumPy replicas
# ---------------------------------------------------------------------------


def _prop_dispatch_scores(seed):
    """Device score/est/scaled == the engine's NumPy branch, bit for bit
    (same accumulation order; sparse-division-by-positive-pf pattern)."""
    rs = np.random.RandomState(seed)
    n = int(rs.randint(1, 65))
    kvec = rs.rand(n) < 0.5
    bal = rs.uniform(-10, 10, n) if rs.rand() < 0.5 else None
    prio = rs.uniform(-5, 5, n)
    skips = rs.randint(0, 9, n).astype(np.float64)
    flop = rs.uniform(1e9, 1e14, n)
    pf = np.where(rs.rand(n) < 0.2, 0.0, rs.uniform(1e8, 1e11, n))
    avail = float(rs.choice([0.0, 0.35, 1.0]))

    # inline replica of BatchDispatchEngine.candidate_rows' numpy branch
    scores = W_KEYWORD * kvec
    if bal is not None:
        scores += W_BALANCE * bal
    scores += W_PRIORITY * prio
    scores += W_SKIPPED * np.minimum(skips, 5.0)
    est = np.full(n, np.inf, dtype=np.float64)
    pos = pf > 0.0
    est[pos] = flop[pos] / pf[pos]
    if avail <= 0:
        scaled = np.full(n, np.inf, dtype=np.float64)
    else:
        scaled = est / avail

    js, je, jx = dispatch_scores(
        kvec, bal, prio, skips, flop, pf, avail,
        (W_KEYWORD, W_BALANCE, W_PRIORITY, W_SKIPPED),
    )
    assert np.array_equal(js, scores)
    assert np.array_equal(je, est)
    assert np.array_equal(jx, scaled)


def _prop_dispatch_elig(seed):
    """Rotated eligibility scan == the NumPy roll/compare pipeline."""
    rs = np.random.RandomState(seed)
    n = int(rs.randint(1, 129))
    valid = rs.rand(n) < 0.7
    target = np.where(rs.rand(n) < 0.6, -1, rs.randint(1, 5, n)).astype(np.int64)
    start = int(rs.randint(0, n))
    host_id = int(rs.randint(1, 5))
    tv = np.roll(valid, -start)
    tt = np.roll(target, -start)
    want = tv & ((tt < 0) | (tt == host_id))
    got = dispatch_elig(valid, target, start, host_id)
    assert np.array_equal(got, want)


@pytest.mark.parametrize("seed", range(30))
def test_dispatch_scores_matches_numpy(seed):
    _prop_dispatch_scores(seed)


@pytest.mark.parametrize("seed", range(20))
def test_dispatch_elig_matches_numpy(seed):
    _prop_dispatch_elig(seed)


test_dispatch_scores_hypothesis = hyp(_prop_dispatch_scores, max_examples=60)
test_dispatch_elig_hypothesis = hyp(_prop_dispatch_elig, max_examples=40)


# ---------------------------------------------------------------------------
# client engine: WRR + run-set identity on random fleets
# ---------------------------------------------------------------------------


def _prop_client_identity(seed):
    """Twin feature-dense fleets through ``backend="numpy"`` and
    ``backend="jax"``: identical WRR floats/miss sets, identical run sets
    (content, order, applied state, slice stamps), identical work needs."""
    now = 500.0
    allow_inf = bool(seed % 2)
    A = make_clients(25, seed, allow_inf=allow_inf)
    B = make_clients(25, seed, allow_inf=allow_inf)
    eng_np = BatchClientEngine()
    eng_jx = BatchClientEngine(backend="jax")

    for sa, sb, c in zip(eng_np.wrr_batch(A, now), eng_jx.wrr_batch(B, now), A):
        _assert_wrr_equal(sa, sb, c.host_id)

    runs_a = eng_np.schedule_batch(A, now)
    runs_b = eng_jx.schedule_batch(B, now)
    sig = lambda js: [  # noqa: E731
        (j.instance_id, j.state, j.slice_start, j.deadline_miss) for j in js
    ]
    for ca, cb, ra, rb in zip(A, B, runs_a, runs_b):
        assert sig(ra) == sig(rb), ca.host_id
        assert sig(ca.jobs) == sig(cb.jobs), ca.host_id
        assert sig(ca.running) == sig(cb.running), ca.host_id

    for na, nb in zip(
        eng_np.needs_work_batch(A, now), eng_jx.needs_work_batch(B, now)
    ):
        assert na == nb


@pytest.mark.parametrize("seed", range(6))
def test_client_engine_backend_identity(seed):
    _prop_client_identity(seed)


test_client_engine_hypothesis = hyp(_prop_client_identity, max_examples=6)


# ---------------------------------------------------------------------------
# Pallas quorum_compare digest buckets vs the reference kernel
# ---------------------------------------------------------------------------


def _partition(codes):
    """Label-free view of a grouping: sorted tuple-of-tuples of indices."""
    groups = {}
    for i, c in enumerate(codes):
        groups.setdefault(int(c), []).append(i)
    return sorted(tuple(v) for v in groups.values())


def _ref_group_codes(mat, rtol, atol):
    """The same greedy first-match grouping as ``quorum_group_codes`` but
    with the pure-jnp reference kernel as the pair predicate."""
    n = mat.shape[0]
    codes = np.zeros(n, dtype=np.int64)
    reps = []
    nan_rows = np.isnan(mat).any(axis=1)
    for i in range(n):
        if nan_rows[i]:
            codes[i] = -(i + 1)  # unique stand-in sentinel
            continue
        for g, r in enumerate(reps):
            n_bad, _ = quorum_compare_ref(
                jax.numpy.asarray(mat[i]), jax.numpy.asarray(mat[r]),
                rtol=rtol, atol=atol,
            )
            if int(n_bad) == 0:
                codes[i] = g
                break
        else:
            reps.append(i)
            codes[i] = len(reps) - 1
    return codes


_TOL_BANDS = [(1e-5, 1e-8), (1e-6, 1e-9), (1e-4, 1e-6)]


def _prop_digest_buckets(seed):
    """Pallas-kernel grouping == reference-kernel grouping across tolerance
    bands under the far-from-boundary digest contract; NaN rows are unique
    singletons in both; -0.0 buckets with +0.0."""
    rs = np.random.RandomState(seed)
    d = int(rs.randint(4, 49))
    n_groups = int(rs.randint(1, 4))
    rtol, atol = _TOL_BANDS[int(rs.randint(0, len(_TOL_BANDS)))]
    rows = []
    for g in range(n_groups):
        base = rs.standard_normal(d) * 10.0
        if rs.rand() < 0.5:
            base[rs.rand(d) < 0.3] = 0.0  # exact zeros for the -0.0 corner
        # far-outside-tolerance separation between groups (digest contract)
        base = base + g * (1000.0 * (atol + rtol * 20.0) + 5.0)
        for _ in range(int(rs.randint(1, 4))):
            row = base.copy()
            if rs.rand() < 0.5:
                row[row == 0.0] = -0.0  # must still bucket with +0.0
            rows.append(row)
    if rs.rand() < 0.5:
        bad = rs.standard_normal(d)
        bad[int(rs.randint(0, d))] = np.nan  # NaN rows: always singletons
        rows.append(bad)
    mat = np.stack(rows)[rs.permutation(len(rows))].astype(np.float64)

    got = _partition(quorum_group_codes(mat, rtol, atol))
    want = _partition(_ref_group_codes(mat, rtol, atol))
    assert got == want


@pytest.mark.parametrize("seed", range(12))
def test_quorum_digest_buckets_match_ref(seed):
    _prop_digest_buckets(seed)


test_quorum_digest_hypothesis = hyp(_prop_digest_buckets, max_examples=25)


def test_quorum_digest_negative_zero_and_nan_exact():
    """Deterministic pin of the PR 4 corners: a -0.0 replica groups with
    its +0.0 twin; every NaN-carrying replica is its own group."""
    a = np.array([0.0, 1.0, 2.0, 3.0])
    b = a.copy()
    b[0] = -0.0
    c = a + 100.0
    nan1 = a.copy()
    nan1[2] = np.nan
    nan2 = nan1.copy()
    mat = np.stack([a, b, c, nan1, nan2])
    codes = quorum_group_codes(mat, 1e-5, 1e-8)
    assert codes[0] == codes[1]
    assert codes[2] != codes[0]
    assert len({int(x) for x in codes}) == 4  # {a,b}, {c}, {nan1}, {nan2}
    assert codes[3] != codes[4]


# ---------------------------------------------------------------------------
# world device mirror: dirty-upload regression
# ---------------------------------------------------------------------------


def _mk_world(backend, n_hosts=6, seed=11):
    rng = random.Random(seed)
    world = HostArrays(backend=backend)
    for h in range(n_hosts):
        client = Client(
            host_id=h + 1,
            resources={CPU: ClientResource(CPU, 4, 1e10)},
            prefs=ClientPrefs(),
        )
        client.attach(ProjectAttachment(name="p"))
        world.add_host(h + 1, client, 4)
        for k in range(rng.randrange(1, 5)):
            cj = ClientJob(
                instance_id=h * 100 + k,
                job_id=h * 100 + k,
                project="p",
                app_name="w",
                usage={CPU: rng.choice([0.5, 1.0, 2.0])},
                est_flops=1e10,
                est_flop_count=1e13,
                deadline=1e9,
                state=rng.choice([RunState.RUNNING, RunState.PREEMPTED]),
            )
            client.jobs.append(cj)
            world.add_job(h + 1, cj, actual_total=rng.uniform(40.0, 200.0))
        world.sync_run_state(h + 1)
    return world


def _assert_mirror_matches_host(world):
    """After a sync flush, every device column must equal its host column —
    i.e. the incremental dirty-range upload equals a from-scratch upload."""
    m = world._mirror
    m.sync(world)
    assert not m.dirty and not m.all_dirty
    for name in ("q_total", "q_runtime", "q_frac", "q_running", "q_weight", "busy"):
        dev = np.asarray(getattr(m, name))
        host = getattr(world, name)
        assert np.array_equal(dev, host), name
    assert np.array_equal(np.asarray(m.q_cpu), world.q_usage[CPU])


def test_dirty_upload_after_each_mutation_kind():
    """Drive every ``_touch`` writer between device ticks; the device
    columns must match the host arrays after each pass."""
    world = _mk_world("jax")
    ids = list(world.index)
    world.advance_batch(ids, 30.0)
    _assert_mirror_matches_host(world)

    # set_accrued + sync_run_state
    world.set_accrued(1, 0, 7.25)
    for j in world.clients[world.index[2]].jobs:
        j.state = RunState.RUNNING
    world.sync_run_state(2)
    world.advance_batch(ids, 60.0)
    _assert_mirror_matches_host(world)

    # dirty-host refresh: mutate objects out-of-band, then resync
    c3 = world.clients[world.index[3]]
    if c3.jobs:
        c3.jobs[0].state = RunState.DONE
    world.mark_dirty(3)
    world.resync_host(3)
    _assert_mirror_matches_host(world)

    # churn: remove a host, add a job elsewhere
    world.remove_host(4)
    extra = ClientJob(
        instance_id=9999, job_id=9999, project="p", app_name="w",
        usage={CPU: 1.0}, est_flops=1e10, est_flop_count=1e13,
        deadline=1e9, state=RunState.RUNNING,
    )
    world.clients[world.index[5]].jobs.append(extra)
    world.add_job(5, extra, actual_total=55.0)
    world.sync_run_state(5)
    world.advance_batch([h for h in ids if h != 4], 95.0)
    _assert_mirror_matches_host(world)

    # completion path reads through the same mirror
    done = world.completed_rows_batch([h for h in ids if h != 4])
    for h, rows in done.items():
        i = world.index[h]
        cnt = int(world.q_count[i])
        want = np.flatnonzero(
            world.q_running[:cnt, i]
            & (world.q_runtime[:cnt, i] >= world.q_total[:cnt, i] - 1e-6)
        )
        assert np.array_equal(rows, want), h
    _assert_mirror_matches_host(world)


def test_queue_growth_forces_full_reupload():
    """Growing the queue matrix reallocates host storage; the mirror's
    shape check must catch it and re-upload everything."""
    world = _mk_world("jax", n_hosts=2)
    world.advance_batch([1, 2], 10.0)
    q_before = world.q_total.shape
    c = world.clients[world.index[1]]
    for k in range(world._q + 1):  # force at least one _grow_queue
        cj = ClientJob(
            instance_id=5000 + k, job_id=5000 + k, project="p", app_name="w",
            usage={CPU: 0.5}, est_flops=1e10, est_flop_count=1e13,
            deadline=1e9, state=RunState.PREEMPTED,
        )
        c.jobs.append(cj)
        world.add_job(1, cj, actual_total=80.0)
    assert world.q_total.shape != q_before
    world.advance_batch([1, 2], 40.0)
    _assert_mirror_matches_host(world)


def test_world_backend_twin_parity():
    """A NumPy twin driven through the identical mutation/tick sequence
    stays bitwise identical in accrual state and REC debits."""

    def drive(backend):
        world = _mk_world(backend, seed=23)
        ids = list(world.index)
        for t in (15.0, 47.5, 160.0, 500.0):
            world.advance_batch(ids, t)
            if t == 47.5:
                # host 2's first job has instance id 100 (h=1, k=0)
                if 100 in world.row_of[world.index[2]]:
                    world.set_accrued(2, 100, 3.5)
                world.remove_host(6)
                ids = [h for h in ids if h != 6]
            if t == 160.0:
                done = world.completed_rows_batch(ids)
                for h, rows in done.items():
                    if len(rows):
                        world.remove_rows(h, rows)
        return world

    wn = drive("numpy")
    wj = drive("jax")
    assert np.array_equal(wn.q_runtime, wj.q_runtime)
    assert np.array_equal(wn.q_frac, wj.q_frac)
    assert np.array_equal(wn.busy, wj.busy)
    assert np.array_equal(wn.q_count, wj.q_count)
    for cn, cj in zip(wn.clients, wj.clients):
        if cn is None or cj is None:
            assert cn is None and cj is None
            continue
        recs_n = {k: (a.balance, a.total_used) for k, a in cn.rec.accounts.items()}
        recs_j = {k: (a.balance, a.total_used) for k, a in cj.rec.accounts.items()}
        assert recs_n == recs_j
