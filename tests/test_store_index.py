"""Indexed job store (§5.1 "DB index" analogy): parity with the scan
oracle, pending-queue fault tolerance, sharded deadline handling, and the
index ↔ scan invariant checker."""
import pytest

from repro.core import (
    App,
    AppVersion,
    GridSimulation,
    InstanceOutcome,
    InstanceState,
    Job,
    JobState,
    JobStore,
    Platform,
    ProjectServer,
    Transitioner,
    default_cpu_plan_class,
    fuzzy_comparator,
    make_population,
    next_id,
    reset_ids,
)


def make_server(use_indexes=True, n_daemon_instances=1, purge_delay=1e18,
                min_quorum=2, delay_bound=4 * 3600.0, cache_size=1024):
    server = ProjectServer(
        name="p", purge_delay=purge_delay, n_daemon_instances=n_daemon_instances,
        cache_size=cache_size,
    )
    server.store.use_indexes = use_indexes
    app = App(
        name="w",
        min_quorum=min_quorum,
        init_ninstances=min_quorum,
        delay_bound=delay_bound,
        comparator=fuzzy_comparator(rtol=1e-6, atol=1e-9),
    )
    for osn in ("windows", "mac", "linux"):
        app.add_version(
            AppVersion(
                id=next_id("appver"),
                app_name="w",
                platform=Platform(osn, "x86_64"),
                version_num=1,
                plan_class=default_cpu_plan_class(),
            )
        )
    server.add_app(app)
    return server


def run_sim(use_indexes, n_jobs=40, n_hosts=10, horizon=2 * 86400.0,
            purge_delay=1.25 * 86400.0, **pop_kw):
    reset_ids()
    server = make_server(use_indexes=use_indexes, purge_delay=purge_delay)
    for _ in range(n_jobs):
        server.submit_job(Job(id=next_id("job"), app_name="w",
                              est_flop_count=0.2 * 3600 * 16.5e9))
    pop = make_population(n_hosts, seed=1, **pop_kw)
    sim = GridSimulation(server, pop, seed=3)
    m = sim.run(horizon)
    sim.audit_validation()
    return server, sim, m


class TestOracleParity:
    """An N-day simulation over the indexed store must be *identical* to the
    seed scan-based oracle: same metrics, same job states, same credit."""

    @pytest.mark.parametrize("pop_kw", [
        dict(),
        dict(error_prob=0.05, availability=0.7),
    ], ids=["clean", "faulty"])
    def test_simulation_identical_to_scan_oracle(self, pop_kw):
        srv_idx, sim_idx, m_idx = run_sim(True, **pop_kw)
        srv_scan, sim_scan, m_scan = run_sim(False, **pop_kw)

        assert vars(m_idx) == vars(m_scan)
        jobs_idx = {j: job.state for j, job in srv_idx.store.jobs.items()}
        jobs_scan = {j: job.state for j, job in srv_scan.store.jobs.items()}
        assert jobs_idx == jobs_scan  # includes purge parity: same rows left
        assert srv_idx.counts() == srv_scan.counts()
        assert srv_idx.credit.total == srv_scan.credit.total
        for t_idx, t_scan in zip(srv_idx.transitioners, srv_scan.transitioners):
            assert vars(t_idx.metrics) == vars(t_scan.metrics)
        # some work actually happened in this scenario, and the purger
        # removed completed rows in both runs identically
        assert m_idx.completed_instances > 0
        assert len(srv_idx.store.jobs) < 40

    def test_completed_instances_excludes_crashes(self):
        reset_ids()
        server = make_server()
        for _ in range(12):
            server.submit_job(Job(id=next_id("job"), app_name="w",
                                  est_flop_count=0.1 * 3600 * 16.5e9))
        pop = make_population(6, seed=1)
        for spec in pop:
            spec.crash_prob = 1.0  # every execution crashes: nothing completes
        sim = GridSimulation(server, pop, seed=3)
        sim.run(86400.0)
        sim.audit_validation()
        assert sim.metrics.completed_instances == 0
        assert sim.metrics.instances_executed > 0


class TestPendingQueues:
    """§5.1 fault tolerance: a paused daemon's work accumulates in the
    store's pending queues and drains without loss on resume."""

    def _completed_server(self, n_jobs=8):
        reset_ids()
        server = make_server(min_quorum=1, purge_delay=0.0)
        jobs = [
            server.submit_job(Job(id=next_id("job"), app_name="w", est_flop_count=1e9))
            for _ in range(n_jobs)
        ]
        server.enabled.assimilator = False
        server.enabled.file_deleter = False
        server.enabled.purger = False
        server.tick(0.0)  # creates instances
        version_id = server.store.apps["w"].versions[0].id
        for job in jobs:
            for inst in server.store.job_instances(job.id):
                inst.state = InstanceState.OVER
                inst.outcome = InstanceOutcome.SUCCESS
                inst.output = 1.0
                inst.host_id = 1
                inst.app_version_id = version_id
            job.transition_flag = True
        return server, jobs

    def test_pause_accumulates_then_drains(self):
        server, jobs = self._completed_server()
        store = server.store
        server.tick(1.0)  # transitioner validates; downstream daemons paused
        assert len(store.assimilate_pending) == len(jobs)
        assert not store.delete_pending and not store.purge_pending
        store.check_invariants()

        server.tick(2.0)  # still paused: queues hold, nothing lost
        assert len(store.assimilate_pending) == len(jobs)

        server.enabled.assimilator = True
        server.tick(3.0)  # assimilate drains into the file-deleter queue
        assert not store.assimilate_pending
        assert len(store.delete_pending) == len(jobs)
        assert not store.purge_pending
        store.check_invariants()

        server.enabled.file_deleter = True
        server.enabled.purger = True
        server.tick(4.0)  # delete → purge cascade drains in one pass
        assert not store.delete_pending and not store.purge_pending
        assert not store.jobs  # fully purged, no loss
        store.check_invariants()

    def test_retained_rows_wait_in_purge_heap(self):
        # completed rows inside the retention window (§4) stay heaped: the
        # purger pops nothing until the window passes, instead of
        # re-scanning every retained job each tick
        server, jobs = self._completed_server()
        server.purge_delay = 100.0
        server.enabled.assimilator = True
        server.enabled.file_deleter = True
        server.enabled.purger = True
        server.tick(1.0)  # validate + assimilate + delete; purge gated
        store = server.store
        assert len(store.purge_pending) == len(jobs)
        assert store.purgeable_jobs(50.0 - server.purge_delay) == []
        assert len(store._purge_heap) >= len(jobs)  # nothing consumed
        store.check_invariants()
        server.tick(200.0)  # window passed: everything purges
        assert not store.purge_pending and not store.jobs
        store.check_invariants()

    def test_transitioner_pause_accumulates_flags(self):
        reset_ids()
        server = make_server()
        server.enabled.transitioner = False
        for _ in range(5):
            server.submit_job(Job(id=next_id("job"), app_name="w", est_flop_count=1e9))
        server.tick(0.0)
        assert len(server.store.transition_pending) == 5
        assert not server.store.instances
        server.enabled.transitioner = True
        server.tick(1.0)
        assert not server.store.transition_pending
        assert len(server.store.instances) == 10  # quorum-2 instances created
        server.store.check_invariants()


class TestShardedDeadlines:
    """Satellite fix: `_check_deadlines` honors ID-space sharding — with
    n>1 daemon instances each transitioner mutates only its own shard."""

    @pytest.mark.parametrize("use_indexes", [True, False], ids=["indexed", "scan"])
    def test_each_instance_handles_own_shard(self, use_indexes):
        reset_ids()
        server = make_server(use_indexes=use_indexes, n_daemon_instances=2,
                             min_quorum=1)
        jobs = [
            server.submit_job(Job(id=next_id("job"), app_name="w",
                                  est_flop_count=1e9, delay_bound=100.0))
            for _ in range(6)
        ]
        for t in server.transitioners:
            t.tick(0.0)
        for job in jobs:
            for inst in server.store.job_instances(job.id):
                inst.state = InstanceState.IN_PROGRESS
                inst.deadline = 100.0

        t0 = server.transitioners[0]
        t0.tick(200.0)  # only shard job_id % 2 == 0 may be touched
        for job in jobs:
            insts = server.store.job_instances(job.id)
            timed_out = [i for i in insts if i.outcome == InstanceOutcome.NO_REPLY]
            if job.id % 2 == 0:
                assert timed_out, f"job {job.id} in shard 0 not handled"
            else:
                assert not timed_out, f"job {job.id} outside shard 0 was mutated"
        assert t0.metrics.timeouts == 3

        server.transitioners[1].tick(200.0)
        assert server.transitioners[1].metrics.timeouts == 3
        assert all(
            i.outcome == InstanceOutcome.NO_REPLY or i.state == InstanceState.UNSENT
            for job in jobs for i in server.store.job_instances(job.id)
        )
        if use_indexes:
            server.store.check_invariants()


class TestDeleteReadiness:
    """Satellite: the file deleter's outstanding-instance re-check is
    deferred to instance-*terminal* events — a delete-pending job blocked
    on a straggler costs nothing per tick, and promotes into
    ``delete_ready`` the moment its last outstanding instance resolves.
    The ``use_indexes=False`` scan stays the oracle."""

    def _blocked_job(self):
        reset_ids()
        server = make_server(min_quorum=2)
        server.enabled.file_deleter = False
        server.enabled.purger = False
        job = server.submit_job(Job(id=next_id("job"), app_name="w",
                                    est_flop_count=1e9))
        server.tick(0.0)  # creates the quorum-2 instances
        a, b = server.store.job_instances(job.id)
        a.outcome = InstanceOutcome.SUCCESS
        a.state = InstanceState.OVER
        b.state = InstanceState.IN_PROGRESS  # the straggler
        job.assimilated = True  # project-side assimilation done
        return server, job, b

    def test_blocked_until_last_outstanding_instance_resolves(self):
        server, job, straggler = self._blocked_job()
        store = server.store

        assert job.id in store.delete_pending
        assert job.id not in store.delete_ready
        assert store.pending_file_deletion() == []  # indexed: deferred
        store.check_invariants()

        # the scan oracle surfaces the job; the deleter daemon's own
        # outstanding check is what filters it there
        store.use_indexes = False
        assert store.pending_file_deletion() == [job]
        assert server.delete_files(1.0) == 0
        assert not job.files_deleted
        store.use_indexes = True

        # instance-terminal event: the straggler resolves → ready
        straggler.outcome = InstanceOutcome.NO_REPLY
        straggler.state = InstanceState.OVER
        assert job.id in store.delete_ready
        assert store.pending_file_deletion() == [job]
        store.check_invariants()

        assert server.delete_files(2.0) == 1
        assert job.files_deleted
        assert job.id not in store.delete_ready  # reindexed on files_deleted
        assert job.id in store.purge_pending
        store.check_invariants()

    def test_instance_reset_blocks_again(self):
        # UNSENT is outstanding too: a retry instance created after
        # assimilation re-blocks the job until it resolves
        server, job, straggler = self._blocked_job()
        store = server.store
        straggler.outcome = InstanceOutcome.NO_REPLY
        straggler.state = InstanceState.OVER
        assert job.id in store.delete_ready

        retry = store.create_instance(job)  # new UNSENT row
        assert job.id not in store.delete_ready
        assert store.pending_file_deletion() == []
        store.check_invariants()

        retry.state = InstanceState.IN_PROGRESS
        assert job.id not in store.delete_ready
        retry.state = InstanceState.OVER
        assert job.id in store.delete_ready
        store.check_invariants()


class TestStoreIndexes:
    def _store(self, min_quorum=2):
        reset_ids()
        store = JobStore()
        app = App(name="a", min_quorum=min_quorum, init_ninstances=min_quorum)
        app.add_version(
            AppVersion(
                id=next_id("appver"),
                app_name="a",
                platform=Platform("windows", "x86_64"),
                version_num=1,
                plan_class=default_cpu_plan_class(),
            )
        )
        store.add_app(app)
        return store

    def test_unsent_queue_lazy_compaction(self):
        store = self._store()
        job = store.submit_job(Job(id=next_id("job"), app_name="a", est_flop_count=1e9))
        insts = [store.create_instance(job) for _ in range(10)]
        # dispatch (invalidate) the first three and one mid-queue entry
        for i in (0, 1, 2, 5):
            insts[i].state = InstanceState.IN_PROGRESS
        got = store.unsent_instances("a", limit=4)
        assert [g.id for g in got] == [insts[3].id, insts[4].id, insts[6].id, insts[7].id]
        q = store._unsent["a"]
        # stale head entries were dropped; the queue was not rebuilt past
        # the walk point (the mid-queue stale entry survives until it
        # surfaces at the head)
        assert q[0] == insts[3].id
        assert insts[5].id in q
        assert insts[9].id in q

    def test_requeue_on_state_reset(self):
        store = self._store(min_quorum=1)
        job = store.submit_job(Job(id=next_id("job"), app_name="a", est_flop_count=1e9))
        inst = store.create_instance(job)
        inst.state = InstanceState.IN_PROGRESS
        assert store.unsent_instances("a") == []
        inst.state = InstanceState.UNSENT  # row returns to the dispatch pool
        assert [i.id for i in store.unsent_instances("a")] == [inst.id]
        store.check_invariants()

    def test_requeue_never_duplicates_queued_entry(self):
        # a row flipping UNSENT -> IN_PROGRESS -> UNSENT while its original
        # entry is still mid-queue must not appear twice
        store = self._store(min_quorum=1)
        job = store.submit_job(Job(id=next_id("job"), app_name="a", est_flop_count=1e9))
        i1, i2 = store.create_instance(job), store.create_instance(job)
        i2.state = InstanceState.IN_PROGRESS  # i2's entry goes stale mid-queue
        i2.state = InstanceState.UNSENT  # ...and live again, not re-appended
        got = store.unsent_instances("a", limit=10)
        assert [g.id for g in got] == [i1.id, i2.id]
        store.check_invariants()

    def test_feeder_refills_past_cached_queue_head(self):
        # backlog >> cache: the oldest UNSENT rows are the cached ones; the
        # refill must look past them instead of starving (in-cache ids are
        # excluded inside the queue walk, not after the limit)
        reset_ids()
        server = make_server(min_quorum=1, cache_size=8)
        for _ in range(40):
            server.submit_job(Job(id=next_id("job"), app_name="w", est_flop_count=1e9))
        server.tick(0.0)
        feeder = server.feeder
        cached = [s for s in feeder.slots if s is not None]
        assert len(cached) == 8
        for s in cached[:4]:  # dispatch half the cache
            server.store.instances[s.instance_id].state = InstanceState.IN_PROGRESS
            feeder.clear_slot(s.instance_id)
        assert sum(1 for s in feeder.slots if s is not None) == 4
        assert feeder.fill() == 4  # refilled from past the cached queue head
        live = [s for s in feeder.slots if s is not None and not feeder._stale(s)]
        assert len(live) == 8
        assert len({s.instance_id for s in live}) == 8
        server.store.check_invariants()

    def test_slow_check_index_matches_scan(self):
        from repro.core.types import Host, ProcessingResource, ResourceType

        store = self._store()
        # two hosts owned by the same volunteer (§6.4: one per volunteer)
        for hid, vol in ((1, 7), (2, 7), (3, 8)):
            store.add_host(Host(
                id=hid,
                platforms=(Platform("windows", "x86_64"),),
                resources={ResourceType.CPU: ProcessingResource(ResourceType.CPU, 4, 1e10)},
                volunteer_id=vol,
            ))
        job = store.submit_job(Job(id=next_id("job"), app_name="a", est_flop_count=1e9))
        inst = store.create_instance(job)
        inst.state = InstanceState.IN_PROGRESS
        inst.host_id = 1
        for hid, expect in ((1, True), (2, True), (3, False)):
            store.use_indexes = True
            assert store.host_has_instance_of_job(hid, job.id) is expect
            store.use_indexes = False
            assert store.host_has_instance_of_job(hid, job.id) is expect
        store.use_indexes = True
        store.check_invariants()

    def test_deadline_heap_skips_stale_entries(self):
        store = self._store(min_quorum=1)
        job = store.submit_job(Job(id=next_id("job"), app_name="a", est_flop_count=1e9))
        a, b = store.create_instance(job), store.create_instance(job)
        for inst in (a, b):
            inst.state = InstanceState.IN_PROGRESS
            inst.deadline = 50.0
        a.state = InstanceState.OVER  # completed before deadline: entry stale
        b.deadline = 80.0  # extended: the 50.0 entry is stale
        assert store.expired_instances(60.0) == []
        assert store.expired_instances(90.0) == [b]
        assert store.expired_instances(90.0) == []  # popped exactly once

    def test_invariant_checker_detects_corruption(self):
        store = self._store()
        job = store.submit_job(Job(id=next_id("job"), app_name="a", est_flop_count=1e9))
        store.check_invariants()
        store.transition_pending.discard(job.id)  # corrupt an index
        with pytest.raises(AssertionError, match="transition_pending"):
            store.check_invariants()

    def test_batch_completion_counter(self):
        reset_ids()
        server = make_server(min_quorum=1)
        jobs = [Job(id=next_id("job"), app_name="w", est_flop_count=1e9) for _ in range(3)]
        batch = server.submit_batch(jobs, submitter="s", now=0.0)
        server.tick(0.0)
        store = server.store
        assert store._batch_open[batch.id] == 3
        assert not store.batch_done(batch.id)
        for job in jobs[:2]:
            job.state = JobState.SUCCESS
        assert not store.batch_done(batch.id)
        assert not store.batch_done_pending
        jobs[2].state = JobState.SUCCESS
        assert store.batch_done(batch.id)
        assert store.batch_done_pending == {batch.id}
        server._update_batches(5.0)
        assert batch.completed_time == 5.0
        assert not store.batch_done_pending
        store.check_invariants()

    def test_batch_reopened_by_late_submission(self):
        # submitting into a momentarily-complete batch must clear its done
        # flag: completed_time is only stamped once the batch truly drains
        reset_ids()
        server = make_server(min_quorum=1)
        first = Job(id=next_id("job"), app_name="w", est_flop_count=1e9)
        batch = server.submit_batch([first], submitter="s", now=0.0)
        server.tick(0.0)
        first.state = JobState.SUCCESS
        store = server.store
        assert store.batch_done_pending == {batch.id}

        late = Job(id=next_id("job"), app_name="w", est_flop_count=1e9,
                   batch_id=batch.id, submitter="s")
        server.submit_job(late, now=1.0)
        assert not store.batch_done_pending
        server._update_batches(2.0)
        assert batch.completed_time is None  # still open
        store.check_invariants()

        late.state = JobState.SUCCESS
        assert store.batch_done_pending == {batch.id}
        server._update_batches(3.0)
        assert batch.completed_time == 3.0
        store.check_invariants()
