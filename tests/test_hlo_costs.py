"""The while-trip-aware HLO cost parser vs known ground truth."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.hlo_costs import analyze_module


def test_plain_matmul_flops_exact():
    m, k, n = 256, 512, 128
    f = jax.jit(lambda a, b: a @ b)
    c = f.lower(
        jax.ShapeDtypeStruct((m, k), jnp.float32),
        jax.ShapeDtypeStruct((k, n), jnp.float32),
    ).compile()
    costs = analyze_module(c.as_text())
    assert costs.flops == pytest.approx(2 * m * k * n, rel=0.01)


def test_scan_trip_count_multiplies_flops():
    m = 128
    w = jax.ShapeDtypeStruct((m, m), jnp.float32)
    x = jax.ShapeDtypeStruct((m, m), jnp.float32)

    def run(trips):
        def f(x0, w0):
            def body(carry, _):
                return jnp.tanh(carry @ w0), None

            out, _ = jax.lax.scan(body, x0, None, length=trips)
            return out

        c = jax.jit(f).lower(x, w).compile()
        return analyze_module(c.as_text())

    c3 = run(3)
    c9 = run(9)
    assert 3 in c3.while_trips.values() or any(v == 3 for v in c3.while_trips.values())
    per_trip = 2 * m**3
    assert c3.flops == pytest.approx(3 * per_trip, rel=0.05)
    assert c9.flops == pytest.approx(9 * per_trip, rel=0.05)


def test_scan_vs_unrolled_agree():
    """The parser on a scanned module == XLA's own count on the unrolled
    equivalent (where XLA's body-once bug doesn't apply)."""
    m, trips = 64, 5
    x = jax.ShapeDtypeStruct((m, m), jnp.float32)
    w = jax.ShapeDtypeStruct((m, m), jnp.float32)

    def scanned(x0, w0):
        def body(c, _):
            return c @ w0, None

        out, _ = jax.lax.scan(body, x0, None, length=trips)
        return out

    def unrolled(x0, w0):
        c = x0
        for _ in range(trips):
            c = c @ w0
        return c

    cs = jax.jit(scanned).lower(x, w).compile()
    cu = jax.jit(unrolled).lower(x, w).compile()
    parsed = analyze_module(cs.as_text())
    ca = cu.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    assert parsed.flops == pytest.approx(float(ca["flops"]), rel=0.05)


def test_nested_scan_multipliers():
    m = 32
    x = jax.ShapeDtypeStruct((m, m), jnp.float32)
    w = jax.ShapeDtypeStruct((m, m), jnp.float32)

    def f(x0, w0):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w0, None

            c2, _ = jax.lax.scan(inner, c, None, length=4)
            return c2, None

        out, _ = jax.lax.scan(outer, x0, None, length=3)
        return out

    c = jax.jit(f).lower(x, w).compile()
    costs = analyze_module(c.as_text())
    assert costs.flops == pytest.approx(12 * 2 * m**3, rel=0.05)


def test_collectives_counted_empty_on_single_device():
    f = jax.jit(lambda a: a + 1)
    c = f.lower(jax.ShapeDtypeStruct((8,), jnp.float32)).compile()
    costs = analyze_module(c.as_text())
    assert costs.total_collective_bytes == 0
