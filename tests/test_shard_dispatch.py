"""Shard-aware federated dispatch (§5.1 scale-out).

Pins the parity contract of ``core/shard.py``:

  * host→shard affinity routing (pinned overrides, modulo default);
  * the shard-parity test the ISSUE asks for — under a pinned affinity map
    equal to round-robin order, the union of per-shard ``rpc_batch``
    assignments equals sequential affinity-routed dispatch, per-request and
    per-store-field (migration disabled so both twins see identical
    ownership);
  * single-shard configs never construct a ShardMap (bit-identical to the
    unsharded goldens by construction);
  * deterministic work migration: a starved shard steals the lowest-index
    live slots from ring-order donors, donors never drop below the
    watermark, and any move bumps the feeder generation.
"""
import pytest

from repro.core import (
    App,
    AppVersion,
    Host,
    Job,
    Platform,
    ProcessingResource,
    ProjectServer,
    ResourceRequest,
    ResourceType,
    ScheduleRequest,
    ShardMap,
    ShardPolicy,
    default_cpu_plan_class,
    next_id,
    reset_ids,
)

OSES = ("windows", "mac", "linux")

N_SHARDS = 3
N_HOSTS = 9


def _reply_sig(replies):
    return [
        (
            r.request_delay,
            tuple(r.delete_sticky),
            tuple(
                (d.job.id, d.instance.id, d.version.id, d.est_flops, d.est_runtime)
                for d in r.jobs
            ),
        )
        for r in replies
    ]


def _store_sig(server):
    inst = tuple(
        (i.id, i.state.value, i.host_id, i.app_version_id, i.sent_time, i.deadline)
        for i in sorted(server.store.instances.values(), key=lambda x: x.id)
    )
    jobs = tuple(
        (j.id, j.hr_class, j.hav_version_id, j.min_quorum, j.transition_flag)
        for j in sorted(server.store.jobs.values(), key=lambda x: x.id)
    )
    slots = tuple(
        (s.instance_id, s.taken, s.skipped) if s is not None else None
        for s in server.feeder.slots
    )
    return inst, jobs, slots


def _pinned_affinity():
    """host i (1-based) → shard (i-1) % N: with requests arriving in host
    order, affinity routing visits shards 0,1,2,0,1,2,… — exactly the
    round-robin order of the unsharded sequential path."""
    return {i + 1: i % N_SHARDS for i in range(N_HOSTS)}


def _make_server(*, sharded, vector=False, policy=None, affinity=None,
                 n_jobs=60, cache_size=48):
    reset_ids()
    server = ProjectServer(
        name="p",
        cache_size=cache_size,
        n_scheduler_instances=N_SHARDS,
        vector_dispatch=vector,
        sharded_dispatch=sharded,
        shard_affinity=affinity,
        shard_policy=policy,
    )
    app = App(name="a", min_quorum=1, init_ninstances=1)
    for osn in OSES:
        app.add_version(
            AppVersion(
                id=next_id("appver"),
                app_name="a",
                platform=Platform(osn, "x86_64"),
                version_num=1,
                plan_class=default_cpu_plan_class(),
            )
        )
    server.add_app(app)
    for _ in range(n_jobs):
        server.submit_job(
            Job(id=next_id("job"), app_name="a", est_flop_count=1e12), 0.0
        )
    hosts = []
    for i in range(N_HOSTS):
        h = Host(
            id=i + 1,
            platforms=(Platform(OSES[i % 3], "x86_64"),),
            resources={
                ResourceType.CPU: ProcessingResource(ResourceType.CPU, 4, 2e10)
            },
            volunteer_id=i + 1,
        )
        server.add_host(h)
        hosts.append(h)
    server.tick(0.0)
    return server, hosts


def _requests(hosts):
    return [
        ScheduleRequest(
            host_id=h.id,
            requests={
                ResourceType.CPU: ResourceRequest(req_runtime=3000.0, req_idle=1)
            },
        )
        for h in hosts
    ]


# ---------------------------------------------------------------------------
# affinity routing
# ---------------------------------------------------------------------------


class TestAffinity:
    def test_modulo_default_and_pinned_override(self):
        sm = ShardMap(n_shards=3, cache_size=12, affinity={7: 2, 9: 5})
        assert [sm.shard_of(h) for h in (1, 2, 3, 4)] == [1, 2, 0, 1]
        assert sm.shard_of(7) == 2  # pinned
        assert sm.shard_of(9) == 5 % 3  # pinned values normalized mod n
        assert sm.shard_of(10) == 1  # unlisted falls back to modulo

    def test_rpc_routes_by_affinity_not_round_robin(self):
        server, hosts = _make_server(
            sharded=True, policy=ShardPolicy(low_watermark=0)
        )
        assert server.shard_map is not None
        # two back-to-back requests from the same host hit the same shard
        # (round-robin would alternate instances)
        for _ in range(2):
            server.rpc(_requests(hosts)[3], 0.0)  # host 4 → shard 1
        stats = server.shard_map.utilization()
        assert stats[4 % N_SHARDS]["requests"] == 2
        assert all(
            s["requests"] == 0 for s in stats if s["shard"] != 4 % N_SHARDS
        )

    def test_remove_host_purges_pinned_affinity(self):
        # churn purge completeness: a departing host leaves no affinity
        # entry behind; a rejoin under the same id falls back to modulo
        server, _hosts = _make_server(
            sharded=True, policy=ShardPolicy(low_watermark=0),
            affinity={5: 2},
        )
        assert server.shard_map.shard_of(5) == 2
        server.remove_host(5)
        assert 5 not in server.shard_map.affinity
        assert server.shard_map.shard_of(5) == 5 % N_SHARDS


# ---------------------------------------------------------------------------
# the pinned shard-parity contract
# ---------------------------------------------------------------------------


class TestShardParity:
    """Union of per-shard ``rpc_batch`` assignments == sequential
    affinity-routed dispatch, under a pinned affinity map equal to
    round-robin order, with migration disabled."""

    @pytest.mark.parametrize("vector", [False, True], ids=["scalar", "vector"])
    def test_batch_equals_sequential_affinity_routed(self, vector):
        aff = _pinned_affinity()
        pol = ShardPolicy(low_watermark=0)  # keep twin ownership identical
        server_a, hosts_a = _make_server(
            sharded=True, vector=vector, policy=pol, affinity=aff
        )
        server_b, hosts_b = _make_server(
            sharded=True, vector=vector, policy=pol, affinity=aff
        )
        reqs_a = _requests(hosts_a)
        reqs_b = _requests(hosts_b)

        # the pinned map makes affinity order == round-robin order
        assert [server_a.shard_map.shard_of(r.host_id) for r in reqs_a] == [
            i % N_SHARDS for i in range(len(reqs_a))
        ]

        # snapshot slot positions first: the dispatch tail clears a slot
        # once its instance is sent
        pos_of = {
            s.instance_id: p
            for p, s in enumerate(server_b.feeder.slots)
            if s is not None
        }

        replies_a = [server_a.rpc(r, 0.0) for r in reqs_a]  # sequential twin
        replies_b = server_b.rpc_batch(reqs_b, 0.0)  # one per-shard pass each

        assert _reply_sig(replies_a) == _reply_sig(replies_b)
        assert _store_sig(server_a) == _store_sig(server_b)

        # ISSUE wording: the union of per-shard assignments matches too
        def assigned(replies, reqs):
            return {
                (req.host_id, d.job.id)
                for req, rep in zip(reqs, replies)
                for d in rep.jobs
            }

        union_b = assigned(replies_b, reqs_b)
        assert union_b == assigned(replies_a, reqs_a)
        assert union_b  # the workload actually dispatched something

        # shards really partitioned the work: every dispatched job came out
        # of a slot owned by the handling shard's slice
        for req, rep in zip(reqs_b, replies_b):
            shard = server_b.shard_map.shard_of(req.host_id)
            owned = set(server_b.shard_map.owned_positions(shard))
            for d in rep.jobs:
                assert pos_of[d.instance.id] in owned

    def test_single_shard_config_builds_no_shard_map(self):
        # the bit-identical-goldens guarantee is structural: one scheduler
        # instance → no ShardMap → the seed code path, untouched
        reset_ids()
        server = ProjectServer(name="p", cache_size=16)
        assert server.shard_map is None
        reset_ids()
        server = ProjectServer(name="p", cache_size=16, n_scheduler_instances=3,
                               sharded_dispatch=False)
        assert server.shard_map is None  # explicit opt-out keeps the fallback


# ---------------------------------------------------------------------------
# work migration
# ---------------------------------------------------------------------------


class TestMigration:
    def _starved_server(self, policy):
        server, hosts = _make_server(
            sharded=True, policy=policy, n_jobs=80, cache_size=24
        )
        sm = server.shard_map
        # drain shard 0: mark every slot it owns taken (dispatched)
        for p in sm.owned_positions(0):
            slot = server.feeder.slots[p]
            if slot is not None:
                slot.taken = True
        return server, sm

    def test_starved_shard_steals_lowest_index_live_slots(self):
        pol = ShardPolicy(low_watermark=3, refill_target=5, max_moves=64)
        server, sm = self._starved_server(pol)
        donors_before = {
            s: sm.live_count(server.feeder, s) for s in range(1, N_SHARDS)
        }
        version_before = server.feeder.version
        expected_steal = min(
            p
            for s in range(1, N_SHARDS)
            for p in sm.owned_positions(s)
            if server.feeder.slots[p] is not None
            and not server.feeder.slots[p].taken
        )

        moved = sm.rebalance(server.feeder, 0)

        assert moved == pol.refill_target
        assert sm.owner[expected_steal] == 0  # lowest-index donor slot first
        assert sm.live_count(server.feeder, 0) == pol.refill_target
        for s, before in donors_before.items():
            assert sm.live_count(server.feeder, s) >= min(before, pol.low_watermark)
        assert sm.stats[0].migrations_in == moved
        assert sum(st.migrations_out for st in sm.stats) == moved
        assert server.feeder.version > version_before  # snapshots rebuild
        server.store.check_invariants()

    def test_donors_never_drop_below_watermark(self):
        pol = ShardPolicy(low_watermark=3, refill_target=64, max_moves=64)
        server, sm = self._starved_server(pol)
        sm.rebalance(server.feeder, 0)
        for s in range(1, N_SHARDS):
            assert sm.live_count(server.feeder, s) >= pol.low_watermark

    def test_zero_watermark_disables_migration(self):
        pol = ShardPolicy(low_watermark=0)
        server, sm = self._starved_server(pol)
        version_before = server.feeder.version
        assert sm.rebalance(server.feeder, 0) == 0
        assert server.feeder.version == version_before
        assert sm.stats[0].migrations_in == 0

    def test_migration_is_deterministic(self):
        pol = ShardPolicy(low_watermark=3, refill_target=5)
        owners = []
        for _ in range(2):
            server, sm = self._starved_server(pol)
            sm.rebalance(server.feeder, 0)
            owners.append(sm.owner.tolist())
        assert owners[0] == owners[1]
