"""Unit tests for the BOINC core middleware (types, backoff, keywords,
allocation, estimation, adaptive replication, credit)."""
import math

import pytest

from repro.core import (
    AdaptiveReplication,
    CreditSystem,
    ExponentialBackoff,
    Host,
    Job,
    JobInstance,
    KeywordPrefs,
    LinearBoundedAllocator,
    Platform,
    ProcessingResource,
    ResourceType,
    RuntimeEstimator,
    default_cpu_plan_class,
    gpu_plan_class,
    hr_class,
    keyword_score,
    next_id,
    reset_ids,
)
from repro.core.credit import (
    COBBLESTONE_SCALE,
    collate_cross_project,
    host_cpid_consensus,
    peak_flop_count,
    volunteer_cpid,
)
from repro.core.types import AppVersion, HRLevel


def make_host(hid=1, flops=16.5e9, ncpus=4, os_name="windows", gpu=None):
    res = {
        ResourceType.CPU: ProcessingResource(ResourceType.CPU, ncpus, flops)
    }
    if gpu:
        res[ResourceType.GPU] = ProcessingResource(ResourceType.GPU, 1, gpu)
    return Host(
        id=hid,
        platforms=(Platform(os_name, "x86_64"),),
        resources=res,
        volunteer_id=hid,
    )


# ---------------------------------------------------------------------------
# backoff (§2.2)
# ---------------------------------------------------------------------------


def test_backoff_exponential_growth_and_cap():
    b = ExponentialBackoff(min_interval=60, max_interval=3600, jitter=0.0)
    assert b.ready(0.0)
    intervals = []
    now = 0.0
    for _ in range(10):
        now = b.register_failure(now)
        intervals.append(b.current_interval())
    assert intervals[0] == 60
    assert intervals[1] == 120
    assert intervals[-1] == 3600  # capped
    b.register_success()
    assert b.ready(now)
    assert b.current_interval() == 0.0


def test_backoff_jitter_bounded():
    b = ExponentialBackoff(min_interval=100, jitter=0.2, seed=42)
    t = b.register_failure(0.0)
    assert 80.0 <= t <= 120.0


# ---------------------------------------------------------------------------
# keywords (§2.4)
# ---------------------------------------------------------------------------


def test_keyword_no_veto():
    prefs = KeywordPrefs.make(yes=["physics"], no=["biomedicine"])
    assert keyword_score(("cancer_research",), prefs) is None  # ancestor "no"
    assert keyword_score(("astrophysics",), prefs) == 1.0  # ancestor "yes"
    assert keyword_score(("mathematics",), prefs) == 0.0


def test_keyword_empty_prefs_neutral():
    assert keyword_score(("physics",), KeywordPrefs()) == 0.0


# ---------------------------------------------------------------------------
# linear-bounded allocation (§3.9)
# ---------------------------------------------------------------------------


def test_allocation_accrues_to_cap_and_debits():
    alloc = LinearBoundedAllocator(default_rate=1.0, default_cap=100.0)
    alloc.add_account("a", now=0.0)
    assert alloc.balance("a", 50.0) == 50.0
    assert alloc.balance("a", 500.0) == 100.0  # capped
    alloc.debit("a", 30.0, 500.0)
    assert alloc.balance("a", 500.0) == 70.0


def test_allocation_prioritizes_sporadic_over_continuous():
    """The paper's claim: small/sporadic submitters outrank heavy users."""
    alloc = LinearBoundedAllocator(default_rate=1.0, default_cap=1000.0)
    alloc.add_account("heavy", now=0.0)
    alloc.add_account("sporadic", now=0.0)
    for t in range(1, 50):
        alloc.debit("heavy", 2.0, float(t))  # uses 2x its accrual
    ranked = alloc.ranked(50.0)
    assert ranked[0] == "sporadic"


# ---------------------------------------------------------------------------
# runtime estimation (§6.3)
# ---------------------------------------------------------------------------


def _version(app="app", vid=None):
    return AppVersion(
        id=vid or next_id("appver"),
        app_name=app,
        platform=Platform("windows", "x86_64"),
        version_num=1,
        plan_class=default_cpu_plan_class(),
    )


def test_estimator_fallback_chain():
    reset_ids()
    est = RuntimeEstimator(min_samples=3)
    host = make_host()
    v = _version()
    job = Job(id=1, app_name="app", est_flop_count=16.5e9)  # 1s at peak
    # no samples: peak flops
    assert est.proj_flops(host, v) == pytest.approx(16.5e9)
    assert est.est_runtime(job, host, v) == pytest.approx(1.0)
    # per-version stats after threshold
    other = make_host(hid=2)
    for _ in range(3):
        est.record(other, v, job, runtime=2.0)  # half of peak
    assert est.proj_flops(host, v) == pytest.approx(16.5e9 / 2)
    # host-specific stats dominate once present
    for _ in range(3):
        est.record(host, v, job, runtime=4.0)
    assert est.proj_flops(host, v) == pytest.approx(16.5e9 / 4)


# ---------------------------------------------------------------------------
# adaptive replication (§3.4)
# ---------------------------------------------------------------------------


def test_adaptive_replication_probability_decay():
    ar = AdaptiveReplication(threshold=10, min_probability=0.01, seed=0)
    assert ar.replication_probability(1, 1) == 1.0
    for _ in range(100):
        ar.on_validated(1, 1)
    p = ar.replication_probability(1, 1)
    assert p == pytest.approx(0.1)  # threshold / N
    ar.on_invalid(1, 1)
    assert ar.replication_probability(1, 1) == 1.0  # reset


def test_adaptive_replication_per_pair_granularity():
    ar = AdaptiveReplication(threshold=2)
    for _ in range(10):
        ar.on_validated(1, 7)  # CPU version
    assert ar.replication_probability(1, 7) < 1.0
    assert ar.replication_probability(1, 8) == 1.0  # GPU version separate


# ---------------------------------------------------------------------------
# homogeneous redundancy (§3.4)
# ---------------------------------------------------------------------------


def test_hr_classes():
    a = make_host(1, os_name="windows")
    b = make_host(2, os_name="windows")
    c = make_host(3, os_name="linux")
    b.cpu_model = a.cpu_model
    assert hr_class(a, HRLevel.COARSE) == hr_class(b, HRLevel.COARSE) or True
    # same OS+vendor => same coarse class
    b.cpu_vendor = a.cpu_vendor
    assert hr_class(a, HRLevel.COARSE) == hr_class(b, HRLevel.COARSE)
    assert hr_class(a, HRLevel.COARSE) != hr_class(
        Host(
            id=9,
            platforms=(Platform("linux", "x86_64"),),
            resources={},
            cpu_vendor=a.cpu_vendor,
        ),
        HRLevel.COARSE,
    )
    assert hr_class(a, HRLevel.NONE) == ()


# ---------------------------------------------------------------------------
# credit (§7)
# ---------------------------------------------------------------------------


def test_pfc_and_cobblestones():
    host = make_host(flops=1e9, ncpus=1)  # 1 GFLOPS
    pfc = peak_flop_count(86400.0, {ResourceType.CPU: 1.0}, host)
    assert pfc == pytest.approx(COBBLESTONE_SCALE)  # one day at 1 GFLOPS


def test_credit_grant_drops_outliers():
    vals = [1.0, 1.1, 50.0]  # one cheater claim
    assert CreditSystem.grant_amount(vals) == pytest.approx(1.1)
    assert CreditSystem.grant_amount([2.0]) == pytest.approx(2.0)


def test_credit_grant_keeps_zero_claims():
    """Regression: a legitimately-zero claimed credit is part of the trim
    set — the old ``c > 0`` filter silently dropped it, skewing the
    trimmed average upward (and an all-zero claim set fell through to the
    empty-claims fallback instead of being averaged)."""
    # zero participates in trimming: extremes 0.0 and 6.0 drop, leaving 5.0
    assert CreditSystem.grant_amount([0.0, 5.0, 6.0]) == pytest.approx(5.0)
    # all-zero but valid: average of the zeros, not the empty fallback
    assert CreditSystem.grant_amount([0.0, 0.0]) == 0.0
    assert CreditSystem.grant_amount([0.0]) == 0.0
    # negative values are unset/error sentinels and stay excluded
    assert CreditSystem.grant_amount([-1.0, 2.0]) == pytest.approx(2.0)
    assert CreditSystem.grant_amount([-1.0]) == 0.0


def test_cross_project_credit():
    cpid = volunteer_cpid("Alice@example.com ")
    assert cpid == volunteer_cpid("alice@example.com")
    assert cpid != volunteer_cpid("bob@example.com")
    assert host_cpid_consensus(["b", "a", "c"]) == "a"
    total = collate_cross_project(
        {"p1": {cpid: 10.0}, "p2": {cpid: 5.0, "other": 1.0}}
    )
    assert total[cpid] == pytest.approx(15.0)


# ---------------------------------------------------------------------------
# plan classes (§3.1)
# ---------------------------------------------------------------------------


def test_plan_class_gating():
    pc = gpu_plan_class(min_driver=100)
    no_gpu = make_host()
    assert pc.evaluate(no_gpu) is None
    with_gpu = make_host(gpu=1e12)
    with_gpu.resources[ResourceType.GPU].driver_version = 50
    assert pc.evaluate(with_gpu) is None  # driver too old
    with_gpu.resources[ResourceType.GPU].driver_version = 200
    usage, pf = pc.evaluate(with_gpu)
    assert usage[ResourceType.GPU] == 1.0
    assert pf > 1e12  # gpu + cpu fraction
