"""Scenario-layer benchmark: generation throughput + adversarial containment
(§3.4, §7, §9; ROADMAP item 4).

Two claims:

  * **generation is cheap**: building a fully-layered population — trace
    replay (lognormal sessions + diurnal wave per host), correlated
    outage splice, clique/farm marking — costs microseconds per host, so
    scenario setup never dominates an emulation study (rows
    ``scen_generate/*``).
  * **the defenses contain the adversaries** (§3.4/§7 end to end): a
    3-host always-cheating clique against min_quorum=2 + adaptive
    replication earns zero wrong-accepted canonicals and zero credit, and
    8x credit farmers gain no per-host advantage over the honest mean.
    These are the acceptance bits CI asserts (and the same quantities the
    scenario test matrix golden-pins; the benchmark tracks them as a
    trajectory across PRs).

Smoke mode (CI): ``--smoke`` / ``BENCH_SCENARIOS_SMOKE=1`` trims the
generation population and asserts the acceptance record. Results go to
``benchmarks/BENCH_scenarios.json`` (schema {schema, rows, acceptance}).
"""
from __future__ import annotations

import os
import sys

from .common import RESULTS, emit, timer, write_bench_json

from repro.core import (
    Clique,
    CreditFarm,
    Outage,
    ScenarioSpec,
    TraceReplay,
    generate_population,
    run_spec,
)

DAY = 86400.0
HOUR = 3600.0


def _generation_spec(n_hosts: int) -> ScenarioSpec:
    return ScenarioSpec(
        name="bench_gen",
        seed=12,
        n_hosts=n_hosts,
        trace=TraceReplay(n_timezones=3),
        outage=Outage(start=1.0 * DAY, duration=6 * HOUR, fraction=0.4),
        clique=Clique(size=max(2, n_hosts // 20)),
        farm=CreditFarm(count=max(1, n_hosts // 50), factor=8.0),
        correlated_failures=0.2,
        horizon=3 * DAY,
    )


def run() -> None:
    smoke = "--smoke" in sys.argv or bool(os.environ.get("BENCH_SCENARIOS_SMOKE"))
    start_row = len(RESULTS)

    # -- generation throughput --
    for n_hosts in (500, 2000) if smoke else (2000, 10_000):
        spec = _generation_spec(n_hosts)
        t0 = timer()
        pop = generate_population(spec)
        dt = timer() - t0
        assert len(pop) == n_hosts
        emit(
            f"scen_generate/{n_hosts}",
            dt / n_hosts * 1e6,
            f"layered population in {dt:.3f}s",
        )

    # -- adversarial containment (deterministic seeds; CI acceptance) --
    clique = run_spec(
        ScenarioSpec(
            name="bench_clique", seed=2, adaptive=True, clique=Clique(size=3),
            n_jobs=40,
        )
    )
    clique_wrong = clique.metrics.wrong_accepted
    clique_credit = clique.credit_of_hosts(clique.clique_host_ids())
    emit(
        "scen_clique_adaptive/wrong_accepted",
        float(clique_wrong),
        f"3-clique vs quorum2+adaptive: {clique.clique_quorum_wins()} quorum wins, "
        f"{clique_credit:.3f} credit",
    )

    farm = run_spec(
        ScenarioSpec(
            name="bench_farm", seed=9, farm=CreditFarm(count=2, factor=8.0),
            n_jobs=40, horizon=3 * DAY,
        )
    )
    farm_ids = farm.farm_host_ids()
    per_farmer = farm.credit_of_hosts(farm_ids) / len(farm_ids)
    honest = farm.mean_honest_host_credit()
    emit(
        "scen_credit_farm/advantage",
        per_farmer / honest if honest else 0.0,
        f"8x farmer earns {per_farmer:.3f}/host vs honest {honest:.3f}/host",
    )

    acceptance = {
        "clique_wrong_accepted": clique_wrong,
        "clique_credit": clique_credit,
        "farm_advantage": per_farmer / honest if honest else 0.0,
        "pass": bool(
            clique_wrong == 0
            and clique_credit == 0.0
            and honest > 0.0
            and per_farmer <= 1.5 * honest
        ),
    }
    run.acceptance = acceptance  # picked up by benchmarks.run and CI
    write_bench_json(
        path=str(
            os.path.join(os.path.dirname(__file__), "BENCH_scenarios.json")
        ),
        rows=RESULTS[start_row:],
        extra={"acceptance": acceptance},
    )
    if smoke and not acceptance["pass"]:
        raise SystemExit(f"scenario containment floor failed: {acceptance}")


if __name__ == "__main__":
    run()
