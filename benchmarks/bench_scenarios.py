"""Scenario-layer benchmark: generation throughput + adversarial containment
(§3.4, §7, §9; ROADMAP item 4).

Two claims:

  * **generation is cheap**: building a fully-layered population — trace
    replay (lognormal sessions + diurnal wave per host), correlated
    outage splice, clique/farm marking — costs microseconds per host, so
    scenario setup never dominates an emulation study (rows
    ``scen_generate/*``).
  * **the defenses contain the adversaries** (§3.4/§7 end to end): a
    3-host always-cheating clique against min_quorum=2 + adaptive
    replication earns zero wrong-accepted canonicals and zero credit, and
    8x credit farmers gain no per-host advantage over the honest mean.
    These are the acceptance bits CI asserts (and the same quantities the
    scenario test matrix golden-pins; the benchmark tracks them as a
    trajectory across PRs).
  * **the defense layer is containment without a tax** (§3.4 defense in
    depth): a 6-of-12 clique that defeats 9 quorums undefended contains
    to 1 with DefensePolicy ON, and on an all-honest fleet the full
    stack (suspicion clusters + HR census + quota table) costs <= 10%
    dispatch wall time vs defense-off (rows ``scen_defense/*``). In
    practice the quota cap *reduces* scheduler work — hosts stop
    buffering a day of speculative instances — so the measured ratio
    sits well under 1.0; the 1.10 floor guards the regression direction.

Smoke mode (CI): ``--smoke`` / ``BENCH_SCENARIOS_SMOKE=1`` trims the
generation population and asserts the acceptance record. Results go to
``benchmarks/BENCH_scenarios.json`` (schema {schema, rows, acceptance}).
"""
from __future__ import annotations

import os
import sys

from .common import RESULTS, emit, timer, write_bench_json

from repro.core import (
    Clique,
    CreditFarm,
    DefensePolicy,
    Outage,
    ScenarioSpec,
    TraceReplay,
    generate_population,
    run_spec,
)

DAY = 86400.0
HOUR = 3600.0


def _generation_spec(n_hosts: int) -> ScenarioSpec:
    return ScenarioSpec(
        name="bench_gen",
        seed=12,
        n_hosts=n_hosts,
        trace=TraceReplay(n_timezones=3),
        outage=Outage(start=1.0 * DAY, duration=6 * HOUR, fraction=0.4),
        clique=Clique(size=max(2, n_hosts // 20)),
        farm=CreditFarm(count=max(1, n_hosts // 50), factor=8.0),
        correlated_failures=0.2,
        horizon=3 * DAY,
    )


def run() -> None:
    smoke = "--smoke" in sys.argv or bool(os.environ.get("BENCH_SCENARIOS_SMOKE"))
    start_row = len(RESULTS)

    # -- generation throughput --
    for n_hosts in (500, 2000) if smoke else (2000, 10_000):
        spec = _generation_spec(n_hosts)
        t0 = timer()
        pop = generate_population(spec)
        dt = timer() - t0
        assert len(pop) == n_hosts
        emit(
            f"scen_generate/{n_hosts}",
            dt / n_hosts * 1e6,
            f"layered population in {dt:.3f}s",
        )

    # -- adversarial containment (deterministic seeds; CI acceptance) --
    clique = run_spec(
        ScenarioSpec(
            name="bench_clique", seed=2, adaptive=True, clique=Clique(size=3),
            n_jobs=40,
        )
    )
    clique_wrong = clique.metrics.wrong_accepted
    clique_credit = clique.credit_of_hosts(clique.clique_host_ids())
    emit(
        "scen_clique_adaptive/wrong_accepted",
        float(clique_wrong),
        f"3-clique vs quorum2+adaptive: {clique.clique_quorum_wins()} quorum wins, "
        f"{clique_credit:.3f} credit",
    )

    farm = run_spec(
        ScenarioSpec(
            name="bench_farm", seed=9, farm=CreditFarm(count=2, factor=8.0),
            n_jobs=40, horizon=3 * DAY,
        )
    )
    farm_ids = farm.farm_host_ids()
    per_farmer = farm.credit_of_hosts(farm_ids) / len(farm_ids)
    honest = farm.mean_honest_host_credit()
    emit(
        "scen_credit_farm/advantage",
        per_farmer / honest if honest else 0.0,
        f"8x farmer earns {per_farmer:.3f}/host vs honest {honest:.3f}/host",
    )

    # -- defense-in-depth: containment + dispatch-overhead floor --
    half = dict(name="bench_half_clique", seed=2, clique=Clique(size=6),
                n_jobs=40)
    undefended = run_spec(ScenarioSpec(**half))
    defended = run_spec(ScenarioSpec(**{**half, "defense": DefensePolicy()}))
    def_wrong, undef_wrong = (defended.metrics.wrong_accepted,
                              undefended.metrics.wrong_accepted)
    emit(
        "scen_defense/contained_wrong_accepted",
        float(def_wrong),
        f"6-of-12 clique: {undef_wrong} defeated quorums undefended -> "
        f"{def_wrong} with DefensePolicy",
    )

    # honest large fleet, epoch-batched world: wall-time ratio ON/OFF
    # (min of 2 reps per side to shave scheduler/GC noise)
    ovh_hosts, ovh_jobs = (1000, 300) if smoke else (10_000, 3000)

    def _timed(defense):
        best = float("inf")
        for _ in range(2):
            spec = ScenarioSpec(
                name="bench_defense_ovh", seed=12, n_hosts=ovh_hosts,
                n_jobs=ovh_jobs, horizon=0.5 * DAY, est_hours=0.05,
                availability=0.9, defense=defense,
            )
            t0 = timer()
            r = run_spec(spec, epoch=60.0)
            best = min(best, timer() - t0)
            assert r.server.counts()["jobs_success"] == ovh_jobs
        return best

    t_off = _timed(None)
    t_on = _timed(DefensePolicy())
    ovh_ratio = t_on / t_off
    emit(
        f"scen_defense/dispatch_overhead_{ovh_hosts}",
        ovh_ratio,
        f"honest fleet {ovh_hosts} hosts: defense-on {t_on:.2f}s vs "
        f"off {t_off:.2f}s",
    )

    acceptance = {
        "clique_wrong_accepted": clique_wrong,
        "clique_credit": clique_credit,
        "farm_advantage": per_farmer / honest if honest else 0.0,
        "defense_wrong_accepted": def_wrong,
        "undefended_wrong_accepted": undef_wrong,
        "defense_overhead_ratio": ovh_ratio,
        "pass": bool(
            clique_wrong == 0
            and clique_credit == 0.0
            and honest > 0.0
            and per_farmer <= 1.5 * honest
            and def_wrong <= 1
            and def_wrong < undef_wrong
            and ovh_ratio <= 1.10
        ),
    }
    run.acceptance = acceptance  # picked up by benchmarks.run and CI
    write_bench_json(
        path=str(
            os.path.join(os.path.dirname(__file__), "BENCH_scenarios.json")
        ),
        rows=RESULTS[start_row:],
        extra={"acceptance": acceptance},
    )
    if smoke and not acceptance["pass"]:
        raise SystemExit(f"scenario containment floor failed: {acceptance}")


if __name__ == "__main__":
    run()
