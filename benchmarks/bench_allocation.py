"""Paper claim (§3.9): the linear-bounded allocation model "prioritizes
small batches, thereby minimizing average batch turnaround" given a mix of
continuous and sporadic workloads. Compares small-batch turnaround with the
allocator against a share-blind FIFO baseline."""
from __future__ import annotations

from .common import emit, make_project, timer

from repro.core import GridSimulation, Job, make_population, next_id, reset_ids


def _run(use_allocator: bool):
    reset_ids()
    server = make_project(min_quorum=1)
    if not use_allocator:
        for s in server.schedulers:
            s.allocator = None  # share-blind baseline
    pop = make_population(24, seed=3, availability=1.0)
    sim = GridSimulation(server, pop, seed=9)

    # continuous heavy submitter: a wave every 2h
    def heavy(now):
        for _ in range(160):
            server.submit_job(
                Job(id=next_id("job"), app_name="work",
                    est_flop_count=0.5 * 3600 * 16.5e9, submitter="heavy"),
                now,
            )

    t = 0.0
    horizon = 4 * 86400.0
    while t < horizon:
        sim.schedule_callback(t, heavy)
        t += 2 * 3600.0

    # sporadic small batches (what the claim is about)
    batches = []

    def small(now):
        b = server.submit_batch(
            [
                Job(id=next_id("job"), app_name="work", est_flop_count=0.25 * 3600 * 16.5e9)
                for _ in range(6)
            ],
            submitter="sporadic",
            now=now,
        )
        batches.append(b)

    for t in (6 * 3600.0, 30 * 3600.0, 54 * 3600.0):
        sim.schedule_callback(t, small)

    sim.run(horizon)
    done = [b for b in batches if b.completed_time is not None]
    if not done:
        return float("inf"), 0
    turn = sum(b.completed_time - b.created_time for b in done) / len(done)
    return turn, len(done)


def run() -> None:
    t0 = timer()
    fair, n_fair = _run(use_allocator=True)
    fifo, n_fifo = _run(use_allocator=False)
    wall = timer() - t0
    emit(
        "small_batch_turnaround",
        wall * 1e6,
        (
            f"linear_bounded_h={fair/3600.0:.2f};baseline_h={fifo/3600.0:.2f};"
            f"completed={n_fair}v{n_fifo};paper_claim=small_batches_prioritized;"
            f"pass={fair <= fifo}"
        ),
    )


if __name__ == "__main__":
    run()
