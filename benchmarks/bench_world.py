"""End-to-end simulator throughput: columnar world + vectorized event loop
vs the per-event scalar oracle (§9, ISSUE 5).

Runs the same churn+availability scenario — the regime the paper's scaling
story targets (§1.1 availability, §4 device churn) — through
``GridSimulation.run`` twice per population:

  * ``scalar`` — ``vector_world=False`` with the batch engines disabled
    (``coalesce_rpcs=False``, ``batch_clients=False``): the seed per-event
    Python heapq loop over per-host state, every RPC through the scalar
    O(cache) scoring scan, every reschedule through per-host
    ``wrr_simulate``. This is the same scalar-oracle convention the other
    engine benchmarks use (bench_clients, bench_validation).
  * ``vector`` — ``vector_world=True``: epoch-batched event runs over the
    persistent ``HostArrays`` columns, fused accrual/completion passes,
    world-backed client-engine snapshots, and the persistent-snapshot
    vectorized dispatch path.

Both runs share identical simulation semantics (same ``epoch`` event
quantization, same seeds); the vector run's SimMetrics are asserted
bit-identical to the scalar oracle's at the smallest population before
timing (refuse to benchmark diverged engines).

Populations 1k / 10k / 100k hosts with deep §6.2 work buffers. Horizons
shrink with population so the scalar side stays measurable: 1k and 10k are
both timed directly (the 10k floor row is a direct measurement over an
identical event count); at 100k the scalar side is extrapolated from the
10k per-event cost (events scale linearly in hosts; the scalar loop's
per-event cost is population-invariant — if anything it *grows* with
queue depth, making the extrapolation conservative) and flagged as such.

Acceptance floor: **>=5x** wall-clock at the 10k-host population. Smoke
mode (CI): ``--smoke`` / ``BENCH_WORLD_SMOKE=1`` trims to 1k hosts with a
2.5x floor and asserts it. Results go to ``benchmarks/BENCH_world.json``
(schema {schema, rows, acceptance}).
"""
from __future__ import annotations

import os
import sys
from typing import Optional

from .common import RESULTS, emit, timer, write_bench_json

from repro.core import (
    App,
    AppVersion,
    GridSimulation,
    Job,
    Platform,
    ProjectServer,
    default_cpu_plan_class,
    fuzzy_comparator,
    make_population,
    next_id,
    reset_ids,
)

DAY = 86400.0
EPOCH = 60.0
ACCEPTANCE_FLOOR = 5.0  # x wall-clock at the 10k-host population
SMOKE_FLOOR = 2.5  # CI machines are slower and noisier; smaller population
_FLOOR_POP = 10_000


def _build(vector_world: bool, n_hosts: int, horizon: float, scalar_pure: bool):
    reset_ids()
    server = ProjectServer(name="p", purge_delay=1e18)
    app = App(
        name="w",
        min_quorum=2,
        init_ninstances=2,
        delay_bound=4 * 3600.0,
        comparator=fuzzy_comparator(rtol=1e-6, atol=1e-9),
    )
    for osn in ("windows", "mac", "linux"):
        app.add_version(
            AppVersion(
                id=next_id("appver"),
                app_name="w",
                platform=Platform(osn, "x86_64"),
                version_num=1,
                plan_class=default_cpu_plan_class(),
            )
        )
    server.add_app(app)
    pop = make_population(
        n_hosts,
        seed=1,
        availability=0.6,
        churn_rate=1.0 / (2 * DAY),
        horizon=horizon,
    )
    sim = GridSimulation(
        server, pop, seed=3, vector_world=vector_world, epoch=EPOCH
    )
    if scalar_pure:
        sim.coalesce_rpcs = False
        sim.batch_clients = False
    # deep §6.2 buffers: enough backlog that queues fill to the watermark
    for _ in range(n_hosts * 8):
        server.submit_job(
            Job(id=next_id("job"), app_name="w",
                est_flop_count=0.1 * 3600 * 16.5e9),
            0.0,
        )
    return sim


def _run(vector_world: bool, n_hosts: int, horizon: float,
         scalar_pure: bool = False):
    sim = _build(vector_world, n_hosts, horizon, scalar_pure)
    t0 = timer()
    m = sim.run(horizon)
    wall = timer() - t0
    return wall, m


def _verify_parity(n_hosts: int, horizon: float) -> None:
    """Refuse to benchmark diverged engines: whole-sim metrics must be
    bit-identical between the vectorized loop and the scalar event loop.

    The identity is checked against ``vector_world=False`` at default
    flags. The *timed* scalar baseline additionally disables same-tick RPC
    coalescing — same policy code, but coalescing reorders the simulation's
    own stochastic draws (a documented GridSimulation caveat), so its
    trajectory differs statistically, not semantically."""
    _, m_v = _run(True, n_hosts, horizon)
    _, m_s = _run(False, n_hosts, horizon)
    assert vars(m_v) == vars(m_s), "vector world diverged from scalar oracle"


def run() -> None:
    smoke = "--smoke" in sys.argv or bool(os.environ.get("BENCH_WORLD_SMOKE"))
    if smoke:
        # (population, horizon, scalar measured directly?)
        rows = ((1_000, 2.0 * 3600.0, True),)
        floor = SMOKE_FLOOR
    else:
        rows = (
            (1_000, DAY / 8, True),
            (10_000, DAY / 16, True),  # floor row: both sides direct
            (100_000, DAY / 64, False),
        )
        floor = ACCEPTANCE_FLOOR
    floor_pop = rows[-1][0] if smoke else _FLOOR_POP

    _verify_parity(200, 6 * 3600.0)

    start_row = len(RESULTS)
    speedup_at_floor: Optional[float] = None
    scalar_per_event: Optional[float] = None
    for pop, horizon, direct in rows:
        extrapolated = not direct
        if direct:
            scalar_s, m_s = _run(False, pop, horizon, scalar_pure=True)
            events = max(m_s.rpcs + m_s.instances_executed, 1)
            scalar_per_event = scalar_s / events
        vector_s, m_v = _run(True, pop, horizon)
        if extrapolated:
            # events scale ~linearly with population; per-event scalar cost
            # is population-invariant (fixed-size cache scans, per-host WRR)
            events_v = max(m_v.rpcs + m_v.instances_executed, 1)
            scalar_s = (scalar_per_event or 0.0) * events_v
        speedup = scalar_s / vector_s if vector_s > 0 else 0.0
        tag = ";scalar_extrapolated=true" if extrapolated else ""
        emit(
            f"world_run_scalar_{pop}hosts",
            scalar_s * 1e6,
            f"wall_s={scalar_s:.1f}{tag}",
        )
        emit(
            f"world_run_vector_{pop}hosts",
            vector_s * 1e6,
            f"wall_s={vector_s:.1f};executed={m_v.instances_executed}",
        )
        is_floor = pop == floor_pop
        emit(
            f"world_speedup_{pop}hosts",
            0.0,
            f"speedup={speedup:.1f}x"
            + (f";floor={floor:.1f}x;pass={speedup >= floor}" if is_floor else ""),
        )
        if is_floor:
            speedup_at_floor = speedup

    acceptance = {
        "metric": f"end-to-end GridSimulation.run speedup at {floor_pop} hosts",
        "floor": floor,
        "measured": speedup_at_floor,
        "pass": (speedup_at_floor or 0.0) >= floor,
        "smoke": smoke,
    }
    run.acceptance = acceptance  # picked up by benchmarks.run and CI
    write_bench_json(
        path=os.environ.get(
            "BENCH_WORLD_JSON_PATH",
            os.path.join(os.path.dirname(__file__), "BENCH_world.json"),
        ),
        rows=RESULTS[start_row:],
        extra={"acceptance": acceptance},
    )
    if smoke and not acceptance["pass"]:
        raise SystemExit(
            f"bench_world smoke floor failed: {speedup_at_floor:.1f}x < {floor:.1f}x"
        )


if __name__ == "__main__":
    run()
