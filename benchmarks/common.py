"""Shared helpers for the benchmark harness."""
from __future__ import annotations

import json
import os
import sys
import time
from pathlib import Path
from typing import Any, Dict, List, Optional

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core import (  # noqa: E402
    App,
    AppVersion,
    Job,
    Platform,
    ProjectServer,
    default_cpu_plan_class,
    fuzzy_comparator,
    next_id,
    reset_ids,
)

#: Every ``emit`` row of the current process, for machine-readable output
#: (``BENCH_daemons.json``; see ``write_bench_json``).
RESULTS: List[Dict[str, Any]] = []


def emit(name: str, us_per_call: float, derived: str) -> None:
    """The harness output contract: ``name,us_per_call,derived`` CSV."""
    print(f"{name},{us_per_call:.3f},{derived}")
    RESULTS.append({"name": name, "us_per_call": us_per_call, "derived": derived})


def write_bench_json(
    path: Optional[str] = None,
    extra: Optional[Dict[str, Any]] = None,
    rows: Optional[List[Dict[str, Any]]] = None,
) -> str:
    """Dump emitted rows (all of ``RESULTS`` by default, or an explicit
    slice) as JSON so CI can track the perf trajectory. Default path:
    ``benchmarks/BENCH_daemons.json`` (override with ``BENCH_JSON_PATH``)."""
    path = path or os.environ.get(
        "BENCH_JSON_PATH", str(Path(__file__).resolve().parent / "BENCH_daemons.json")
    )
    payload: Dict[str, Any] = {"schema": 1, "rows": RESULTS if rows is None else rows}
    if extra:
        payload.update(extra)
    Path(path).write_text(json.dumps(payload, indent=2) + "\n")
    print(f"wrote {path}", file=sys.stderr)
    return path


def timer() -> float:
    return time.perf_counter()


def make_project(
    name: str = "bench",
    min_quorum: int = 2,
    adaptive: bool = False,
    delay_bound: float = 6 * 3600.0,
    cache_size: int = 1024,
) -> ProjectServer:
    server = ProjectServer(name=name, purge_delay=1e18, cache_size=cache_size)
    app = App(
        name="work",
        min_quorum=min_quorum,
        init_ninstances=min_quorum,
        delay_bound=delay_bound,
        adaptive_replication=adaptive,
        comparator=fuzzy_comparator(rtol=1e-6, atol=1e-9),
    )
    for osn in ("windows", "mac", "linux"):
        app.add_version(
            AppVersion(
                id=next_id("appver"),
                app_name="work",
                platform=Platform(osn, "x86_64"),
                version_num=1,
                plan_class=default_cpu_plan_class(),
            )
        )
    server.add_app(app)
    return server


def submit_jobs(server: ProjectServer, n: int, est_flops: float = 0.25 * 3600 * 16.5e9,
                submitter: str = "default", now: float = 0.0):
    jobs = [
        Job(id=next_id("job"), app_name="work", est_flop_count=est_flops, submitter=submitter)
        for _ in range(n)
    ]
    for j in jobs:
        server.submit_job(j, now)
    return jobs
