"""Pallas kernel micro-benchmarks (interpret mode on CPU: correctness-scale
timings; the derived column reports oracle agreement, which is the portable
claim — TPU wall-clock belongs to the target hardware)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .common import emit, timer

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.quorum_compare.ops import quorum_compare
from repro.kernels.rmsnorm.ops import rmsnorm
from repro.kernels.rmsnorm.ref import rmsnorm_ref
from repro.kernels.ssd_scan.ops import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_ref
from repro.kernels.swiglu.ops import swiglu
from repro.kernels.int8_quant.ops import quantize_dequantize

KEY = jax.random.PRNGKey(0)


def _time(fn, *args, n=3):
    fn(*args)  # compile/warm
    t0 = timer()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (timer() - t0) / n * 1e6


def run() -> None:
    # flash attention
    q = jax.random.normal(KEY, (1, 256, 4, 64), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 256, 2, 64), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 256, 2, 64), jnp.float32)
    us = _time(lambda a, b, c: flash_attention(a, b, c, interpret=True), q, k, v)
    ref = jnp.moveaxis(
        attention_ref(jnp.moveaxis(q, 1, 2), jnp.moveaxis(k, 1, 2), jnp.moveaxis(v, 1, 2)),
        1, 2,
    )
    err = float(jnp.max(jnp.abs(flash_attention(q, k, v, interpret=True) - ref)))
    emit("kernel_flash_attention", us, f"max_err_vs_oracle={err:.2e}")

    # ssd scan
    x = jax.random.normal(KEY, (1, 256, 4, 64), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(KEY, (1, 256, 4))) * 0.05 + 0.001
    A = -jnp.exp(jax.random.normal(KEY, (4,)) * 0.3)
    Bm = jax.random.normal(KEY, (1, 256, 1, 64), jnp.float32) * 0.3
    Cm = jax.random.normal(KEY, (1, 256, 1, 64), jnp.float32) * 0.3
    us = _time(lambda *a: ssd_scan(*a, interpret=True)[0], x, dt, A, Bm, Cm)
    y, _ = ssd_scan(x, dt, A, Bm, Cm, interpret=True)
    yr, _ = ssd_ref(x, dt, A, Bm, Cm)
    emit("kernel_ssd_scan", us, f"max_err_vs_oracle={float(jnp.max(jnp.abs(y - yr))):.2e}")

    # rmsnorm
    xr = jax.random.normal(KEY, (512, 1024), jnp.float32)
    sc = jnp.ones((1024,))
    us = _time(lambda a, b: rmsnorm(a, b, interpret=True), xr, sc)
    err = float(jnp.max(jnp.abs(rmsnorm(xr, sc, interpret=True) - rmsnorm_ref(xr, sc))))
    emit("kernel_rmsnorm", us, f"max_err_vs_oracle={err:.2e}")

    # swiglu
    g = jax.random.normal(KEY, (512, 1024), jnp.float32)
    u = jax.random.normal(jax.random.PRNGKey(5), (512, 1024), jnp.float32)
    us = _time(lambda a, b: swiglu(a, b, interpret=True), g, u)
    emit("kernel_swiglu", us, "fused_gate=1_hbm_pass")

    # quorum compare (the validator hot loop)
    a = jax.random.normal(KEY, (1 << 18,), jnp.float32)
    b = a.at[:100].add(1.0)
    us = _time(lambda x1, x2: quorum_compare(x1, x2, interpret=True)[0], a, b)
    nb, _ = quorum_compare(a, b, interpret=True)
    emit("kernel_quorum_compare", us, f"bad_detected={int(nb)}/100_expected")

    # int8 quant round trip
    xq = jax.random.normal(KEY, (1024, 256), jnp.float32)
    us = _time(lambda z: quantize_dequantize(z), xq)
    err = float(jnp.max(jnp.abs(quantize_dequantize(xq) - xq)))
    emit("kernel_int8_roundtrip", us, f"max_abs_err={err:.4f};compression=4x")


if __name__ == "__main__":
    run()
