"""End-to-end volunteer-grid training: real JAX gradients dispatched as
BOINC jobs through the virtual-time grid, with faults injected. The derived
column reports loss improvement and the FLOPs/credit ledger."""
from __future__ import annotations

from .common import emit, timer

from repro.configs import get_smoke_config
from repro.core import reset_ids
from repro.data import DataConfig
from repro.optim import AdamWConfig
from repro.runtime import GridTrainer


def run() -> None:
    reset_ids()
    cfg = get_smoke_config("qwen3-0.6b").scaled(n_layers=2, d_model=64)
    dc = DataConfig(vocab=cfg.vocab, seq_len=64, batch_size=4, n_shards=2, seed=3)
    oc = AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=40)
    gt = GridTrainer(
        cfg, dc, oc, n_steps=12, n_hosts=8, seed=0,
        adaptive_replication=True, error_prob=0.05, malicious_fraction=0.15,
        availability=0.9,
    )
    t0 = timer()
    r = gt.run()
    wall = timer() - t0
    credit = sum(v for k, v in r.credit_total.items() if k.startswith("host:"))
    emit(
        "grid_train_e2e",
        wall * 1e6 / max(r.steps_completed, 1),
        (
            f"steps={r.steps_completed};loss={r.losses[0]:.3f}->{r.final_loss:.3f};"
            f"wrong_grads_accepted={r.metrics.wrong_accepted};"
            f"replication_overhead={r.metrics.replication_overhead:.2f};"
            f"credit_cobblestones={credit:.2e}"
        ),
    )


if __name__ == "__main__":
    run()
