"""Paper mechanism (§6.1): WRR causes deadline misses that the EDF override
avoids. Compares predicted-miss handling of the client resource scheduler
against a WRR-only variant on a deadline-heavy queue."""
from __future__ import annotations

from .common import emit, timer

from repro.core import Client, ClientJob, ClientPrefs, ClientResource, ProjectAttachment, ResourceType
from repro.core.client import RunState


def _make_client():
    c = Client(
        host_id=1,
        resources={ResourceType.CPU: ClientResource(ResourceType.CPU, 1, 1e9)},
        prefs=ClientPrefs(),
    )
    c.attach(ProjectAttachment(name="p"))
    return c


def _queue():
    # one long low-urgency job + a stream of short deadline-tight jobs
    jobs = [
        ClientJob(
            instance_id=1, job_id=1, project="p", app_name="a",
            usage={ResourceType.CPU: 1.0}, est_flops=1e9,
            est_flop_count=20 * 3600 * 1e9, deadline=1e9,
        )
    ]
    for i in range(4):
        jobs.append(
            ClientJob(
                instance_id=10 + i, job_id=10 + i, project="p", app_name="a",
                usage={ResourceType.CPU: 1.0}, est_flops=1e9,
                est_flop_count=1800 * 1e9, deadline=(i + 1) * 3600.0,
            )
        )
    return jobs


def _simulate(edf: bool) -> int:
    """Run the client to completion in virtual time; count deadline misses."""
    c = _make_client()
    c.jobs = _queue()
    now = 0.0
    misses = 0
    for _ in range(400):
        if not c.jobs:
            break
        running = c.schedule(now)
        if not running:
            break
        if not edf:
            # WRR-only: force queue order (ignore the miss-driven ordering)
            queued = [j for j in c.jobs if j.state != RunState.DONE]
            for j in queued:
                j.state = RunState.PREEMPTED if j is not queued[0] else j.state
            running = queued[:1]
            for j in running:
                j.state = RunState.RUNNING
            c.running = running
        # advance to next completion
        dt = min(j.remaining_estimate() for j in running)
        dt = max(dt, 60.0)
        done = c.advance(dt, now)
        now += dt
        for j in done:
            if now > j.deadline:
                misses += 1
    return misses


def run() -> None:
    t0 = timer()
    wrr_misses = _simulate(edf=False)
    edf_misses = _simulate(edf=True)
    wall = timer() - t0
    emit(
        "deadline_misses_wrr_vs_edf",
        wall * 1e6,
        (
            f"wrr_misses={wrr_misses};wrr_edf_misses={edf_misses};"
            f"paper_claim=edf_avoids_misses;pass={edf_misses < wrr_misses}"
        ),
    )


if __name__ == "__main__":
    run()
