"""Daemon-pass cost at million-job backlogs (§5.1).

The paper's server scales because daemons *enumerate flagged records* via DB
indexes (real BOINC queries ``WHERE transition_time < now``) instead of
table-scanning. This benchmark measures one full ``ProjectServer.tick``
(feeder + transitioner + assimilator + file deleter + purger + batch update)
against a resident backlog of 10k / 100k / 1M jobs at varying dirty
fractions, for both store paths:

  * ``scan``    — ``store.use_indexes=False``: the seed oracle, every daemon
                  pass walks the full job table → tick is O(total rows);
  * ``indexed`` — the maintained-at-mutation-time indexes (state sets,
                  pending queues, deadline heap) → tick is O(dirty rows).

Acceptance floor: **≥20×** tick speedup at 100k resident mostly-quiescent
jobs, and indexed tick cost scaling with the dirty-row count rather than the
table size.

Smoke mode (CI): ``python -m benchmarks.bench_daemons --smoke`` or
``BENCH_DAEMONS_SMOKE=1`` trims the populations. Standalone runs also write
``benchmarks/BENCH_daemons.json`` (machine-readable; includes any rows
already emitted by earlier benchmarks in the same process).
"""
from __future__ import annotations

import os
import statistics
import sys
from typing import Dict, List, Optional, Tuple

from .common import emit, make_project, timer, write_bench_json

from repro.core import Job, next_id, reset_ids

ACCEPTANCE_FLOOR = 20.0  # x speedup at the 100k mostly-quiescent population
_FLOOR_POP = 100_000


def _build_backlog(n_jobs: int, use_indexes: bool):
    """A server with ``n_jobs`` resident quiescent ACTIVE jobs.

    Flags are cleared directly (the observer hooks keep the indexes
    consistent) so the backlog represents steady state: a huge queue of
    admitted work with nothing for the daemons to do.
    """
    reset_ids()
    server = make_project(min_quorum=1, cache_size=1024)
    server.store.use_indexes = use_indexes
    store = server.store
    jobs = [
        Job(
            id=next_id("job"),
            app_name="work",
            est_flop_count=0.25 * 3600 * 16.5e9,
            min_quorum=1,
            init_ninstances=1,
        )
        for _ in range(n_jobs)
    ]
    for j in jobs:
        store.submit_job(j)
    for j in jobs:
        j.transition_flag = False
    return server, jobs


def _retain_completed(server, jobs) -> None:
    """Flip the whole backlog to completed-but-retained rows (§4 retention:
    purge_delay keeps them resident), the long-running-server regime where
    the purger must not re-scan every completed row per tick."""
    from repro.core import JobState

    server.purge_delay = 1e18
    for j in jobs:
        j.state = JobState.SUCCESS
        j.assimilated = True
        j.files_deleted = True


def _measure_tick(server, jobs, n_dirty: int, rounds: int) -> float:
    """Median seconds per ``server.tick`` with ``n_dirty`` re-flagged jobs
    per round (steady state: the first dirty round creates instances, later
    rounds find them outstanding)."""
    dirty: List[Job] = []
    if n_dirty:
        step = max(1, len(jobs) // n_dirty)
        dirty = jobs[:: step][:n_dirty]
    times = []
    now = 60.0
    for r in range(rounds + 1):  # round 0 is warmup
        for j in dirty:
            j.transition_flag = True
        t0 = timer()
        server.tick(now)
        dt = timer() - t0
        if r > 0:
            times.append(dt)
        now += 60.0
    return statistics.median(times)


def _fmt(seconds: float) -> float:
    return seconds * 1e6  # us per tick


def run() -> None:
    smoke = "--smoke" in sys.argv or bool(os.environ.get("BENCH_DAEMONS_SMOKE"))
    if smoke:
        populations: Tuple[int, ...] = (50_000,)
        scan_limit = 50_000
        rounds = 3
        dirty_counts = (0, 500)
    else:
        populations = (10_000, 100_000, 1_000_000)
        scan_limit = 1_000_000  # scan path measured at every size
        rounds = 5
        # fixed dirty *counts* across table sizes, so O(dirty) scaling is
        # directly observable: same dirty work, 100× the resident rows
        dirty_counts = (0, 100, 1_000)

    floor_pop = populations[-1] if smoke else _FLOOR_POP
    speedup_at_floor: Optional[float] = None
    dirty_curve: Dict[int, Dict[int, float]] = {}

    for pop in populations:
        quiescent: Dict[str, float] = {}
        for label, use_indexes in (("scan", False), ("indexed", True)):
            if label == "scan" and pop > scan_limit:
                continue
            server, jobs = _build_backlog(pop, use_indexes)
            for n_dirty in dirty_counts:
                t = _measure_tick(server, jobs, n_dirty, rounds)
                if n_dirty == 0:
                    quiescent[label] = t
                if label == "indexed":
                    dirty_curve.setdefault(pop, {})[n_dirty] = t
                emit(
                    f"daemons_tick_{label}_{pop}jobs_dirty{n_dirty}",
                    _fmt(t),
                    f"tick_ms={t * 1e3:.3f};dirty={n_dirty}",
                )
            if label == "indexed" and use_indexes:
                server.store.check_invariants()
            # completed-but-retained regime: every row terminal, none
            # purgeable — the tick must not re-visit the retained set
            _retain_completed(server, jobs)
            t = _measure_tick(server, jobs, 0, rounds)
            emit(
                f"daemons_tick_{label}_{pop}jobs_retained",
                _fmt(t),
                f"tick_ms={t * 1e3:.3f};retained={pop}",
            )
            quiescent[f"{label}_retained"] = t
            del server, jobs
        if "scan" in quiescent and "indexed" in quiescent:
            speedup = quiescent["scan"] / max(quiescent["indexed"], 1e-12)
            is_floor = pop == floor_pop
            emit(
                f"daemons_speedup_{pop}jobs",
                0.0,
                f"speedup={speedup:.1f}x"
                + (f";floor={ACCEPTANCE_FLOOR:.0f}x;pass={speedup >= ACCEPTANCE_FLOOR}"
                   if is_floor else ""),
            )
            if is_floor:
                speedup_at_floor = speedup
        if "scan_retained" in quiescent and "indexed_retained" in quiescent:
            r_speedup = quiescent["scan_retained"] / max(quiescent["indexed_retained"], 1e-12)
            emit(f"daemons_speedup_{pop}jobs_retained", 0.0, f"speedup={r_speedup:.1f}x")

    # O(dirty) scaling evidence: at fixed dirty count, indexed tick cost must
    # be roughly flat across table sizes (bounded growth), i.e. driven by
    # dirty rows, not resident rows
    if len(dirty_curve) >= 2 and not smoke:
        pops = sorted(dirty_curve)
        lo, hi = pops[0], pops[-1]
        shared = sorted(set(dirty_curve[lo]) & set(dirty_curve[hi]) - {0})
        for n_dirty in shared:
            growth = dirty_curve[hi][n_dirty] / max(dirty_curve[lo][n_dirty], 1e-12)
            emit(
                f"daemons_odirty_{n_dirty}dirty",
                0.0,
                f"tick_{lo}={dirty_curve[lo][n_dirty] * 1e3:.3f}ms;"
                f"tick_{hi}={dirty_curve[hi][n_dirty] * 1e3:.3f}ms;"
                f"rows_ratio={hi // lo}x;time_ratio={growth:.2f}x",
            )

    extra = {
        "acceptance": {
            "metric": f"server.tick speedup at {floor_pop} quiescent jobs",
            "floor": ACCEPTANCE_FLOOR,
            "measured": speedup_at_floor,
            "pass": (speedup_at_floor or 0.0) >= ACCEPTANCE_FLOOR,
            "smoke": smoke,
        }
    }
    run.acceptance = extra["acceptance"]  # picked up by benchmarks.run
    write_bench_json(extra=extra)


if __name__ == "__main__":
    run()
