"""RPC throughput + tail latency through the asyncio service layer (§5.1).

The paper's scheduler is a CGI fleet behind a shared-memory job cache:
many concurrent client RPCs, one cache, several scheduler instances.  This
bench drives the :mod:`repro.service` TCP front with an async load
generator simulating 10k (smoke/full) and 50k (full) concurrent volunteer
clients, and compares:

  baseline  — one scheduler instance, scalar dispatch, no coalescing: the
              dispatcher answers each WORK frame with its own ``rpc`` call
              (sequential per-request cache scans).
  treatment — four shard-affine scheduler instances, vectorized dispatch,
              wave coalescing: concurrent frames drain into ``rpc_batch``
              waves, one batched engine pass per shard.

Acceptance floor (CI-asserted in smoke mode): the multi-shard coalesced
configuration must reach ≥3× the sequential single-instance RPC/s at 10k
concurrent clients.  p50/p95/p99 reply latency and per-shard utilization
rows are recorded alongside throughput.

Smoke mode: ``python -m benchmarks.bench_rpc --smoke`` or
``BENCH_RPC_SMOKE=1`` (skips the 50k-client full row).

Results are written to ``benchmarks/BENCH_rpc.json`` (schema
{schema, rows, acceptance}; path override ``BENCH_RPC_JSON_PATH``).
"""
from __future__ import annotations

import asyncio
import os
import sys
from typing import Optional, Tuple

from .common import RESULTS, emit, write_bench_json

from repro.core import (
    App,
    AppVersion,
    Host,
    Job,
    Platform,
    ProcessingResource,
    ProjectServer,
    ResourceType,
    default_cpu_plan_class,
    next_id,
    reset_ids,
)
from repro.service import LoadReport, SchedulerService, run_load

_OSES = ("windows", "mac", "linux")

# The cache must be large enough that dispatch work (not event-loop churn)
# dominates the RPC: the scalar oracle path costs O(cache²) Python per
# request, which is exactly the §5.1 bottleneck coalescing removes.
_CACHE = 384
_HOSTS = 2048
_JOBS = 20_000


def _make_server(n_shards: int, vector: bool) -> ProjectServer:
    """A single-app min_quorum=1 project with a pre-filled cache, so every
    RPC is a live dispatch attempt (``make_project`` has no
    ``n_scheduler_instances`` knob, hence the local maker)."""
    reset_ids()
    server = ProjectServer(
        name="bench_rpc",
        purge_delay=1e18,
        cache_size=_CACHE,
        n_scheduler_instances=n_shards,
        vector_dispatch=vector,
    )
    app = App(name="work", min_quorum=1, init_ninstances=1)
    for osn in _OSES:
        app.add_version(
            AppVersion(
                id=next_id("appver"),
                app_name="work",
                platform=Platform(osn, "x86_64"),
                version_num=1,
                plan_class=default_cpu_plan_class(),
            )
        )
    server.add_app(app)
    for _ in range(_JOBS):
        server.submit_job(
            Job(id=next_id("job"), app_name="work", est_flop_count=1e12), 0.0
        )
    for i in range(_HOSTS):
        server.add_host(
            Host(
                id=i + 1,
                platforms=(Platform(_OSES[i % 3], "x86_64"),),
                resources={
                    ResourceType.CPU: ProcessingResource(ResourceType.CPU, 8, 2e10)
                },
                volunteer_id=i + 1,
            )
        )
    server.tick(0.0)
    return server


async def _drive(
    server: ProjectServer, coalesce: bool, n_clients: int
) -> Tuple[LoadReport, dict]:
    svc = SchedulerService(server, coalesce=coalesce, max_batch=1024)
    await svc.start()
    try:
        report = await run_load(
            "127.0.0.1", svc.port, n_clients=n_clients, n_conns=64
        )
    finally:
        await svc.stop()
    return report, svc.stats()


def _measure(n_shards: int, vector: bool, coalesce: bool, n_clients: int):
    server = _make_server(n_shards, vector)
    return asyncio.run(_drive(server, coalesce, n_clients))


def _emit_row(label: str, report: LoadReport, stats: dict) -> None:
    emit(
        f"rpc_{label}",
        1e6 / max(report.rpcs_per_s, 1e-9),
        f"rpcs_per_s={report.rpcs_per_s:.0f};p50_ms={report.p50_ms:.1f}"
        f";p95_ms={report.p95_ms:.1f};p99_ms={report.p99_ms:.1f}"
        f";errors={report.errors};max_wave={stats['max_wave']}",
    )
    for row in stats.get("shards", []):
        emit(
            f"rpc_{label}_shard{row['shard']}",
            0.0,
            f"requests={row['requests']};dispatched={row['dispatched']}"
            f";owned_slots={row['owned_slots']}"
            f";migrations_in={row['migrations_in']}",
        )


def run() -> None:
    start_row = len(RESULTS)
    smoke = "--smoke" in sys.argv or bool(os.environ.get("BENCH_RPC_SMOKE"))
    n_clients = 10_000  # the acceptance criterion is pinned at 10k clients
    floor = 3.0

    base_report, base_stats = _measure(
        n_shards=1, vector=False, coalesce=False, n_clients=n_clients
    )
    _emit_row(f"sequential_1shard_{n_clients}c", base_report, base_stats)

    treat_report, treat_stats = _measure(
        n_shards=4, vector=True, coalesce=True, n_clients=n_clients
    )
    _emit_row(f"coalesced_4shard_{n_clients}c", treat_report, treat_stats)

    speedup: Optional[float] = (
        treat_report.rpcs_per_s / base_report.rpcs_per_s
        if base_report.rpcs_per_s > 0
        else None
    )
    emit(
        f"rpc_speedup_{n_clients}c",
        0.0,
        f"speedup={speedup:.1f}x;floor={floor:.0f}x;pass={speedup >= floor}",
    )

    if not smoke:
        big_report, big_stats = _measure(
            n_shards=4, vector=True, coalesce=True, n_clients=50_000
        )
        _emit_row("coalesced_4shard_50000c", big_report, big_stats)

    acceptance = {
        "metric": f"coalesced 4-shard vs sequential RPC/s at {n_clients} clients",
        "floor": floor,
        "measured": speedup,
        "pass": (speedup or 0.0) >= floor,
        "smoke": smoke,
    }
    run.acceptance = acceptance  # picked up by benchmarks.run and CI
    write_bench_json(
        path=os.environ.get(
            "BENCH_RPC_JSON_PATH",
            os.path.join(os.path.dirname(__file__), "BENCH_rpc.json"),
        ),
        rows=RESULTS[start_row:],
        extra={"acceptance": acceptance},
    )
    if smoke and not acceptance["pass"]:
        raise SystemExit(
            f"bench_rpc smoke floor failed: {speedup:.1f}x < {floor:.0f}x"
        )


if __name__ == "__main__":
    run()
