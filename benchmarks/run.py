"""Benchmark harness: one function per paper table/claim.

Prints ``name,us_per_call,derived`` CSV (one line per measurement). Claims
and their paper sections:

  bench_dispatch    S5.1/[17]  hundreds of dispatches per second; fast batch submit
  bench_rpc         S5.1       asyncio service front: coalesced sharded RPC
                               waves vs sequential single-instance dispatch
  bench_daemons     S5.1       indexed store: O(dirty) daemon passes at 1M-job backlogs
  bench_world       S9         columnar world + vectorized event loop vs the
                               per-event scalar simulator at 1k-100k hosts
  bench_clients     S6.1-6.2   vectorized host-population client engine vs scalar ticks
  bench_validation  S3.4/S7    vectorized validation engine vs scalar check_set
                               passes; adaptive replication: overhead -> ~1
  bench_allocation  S3.9       linear-bounded model minimizes small-batch turnaround
  bench_scheduling  S6.1       EDF override avoids WRR deadline misses
  bench_workfetch   S6.2       buffering bounds RPC rate
  bench_credit      S7         device-neutral credit
  bench_scenarios   S3.4/S9    scenario layer: generation throughput;
                               clique/farm adversarial containment
  bench_jax         (TPU adaptation) JAX execution backend vs the NumPy
                               engines at 1M-host scale
  bench_kernels     (TPU adaptation) Pallas kernels vs oracles
  bench_grid_train  (TPU adaptation) end-to-end fault-tolerant grid training

Every emitted row is also collected into machine-readable
``benchmarks/BENCH_daemons.json`` (schema: {schema, rows, acceptance}) so CI
can track the perf trajectory across PRs.
"""
from __future__ import annotations

import sys
import traceback
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))


def main() -> None:
    from . import (
        bench_allocation,
        bench_clients,
        bench_credit,
        bench_daemons,
        bench_dispatch,
        bench_grid_train,
        bench_jax,
        bench_kernels,
        bench_rpc,
        bench_scenarios,
        bench_scheduling,
        bench_validation,
        bench_workfetch,
        bench_world,
    )
    from .common import write_bench_json

    print("name,us_per_call,derived")
    failures = 0
    for mod in (
        bench_dispatch,
        bench_rpc,
        bench_daemons,
        bench_world,
        bench_clients,
        bench_validation,
        bench_allocation,
        bench_scheduling,
        bench_workfetch,
        bench_credit,
        bench_scenarios,
        bench_jax,
        bench_kernels,
        bench_grid_train,
    ):
        try:
            mod.run()
        except Exception:
            failures += 1
            traceback.print_exc()
            print(f"{mod.__name__},0,FAILED")
    # final write includes every module's rows (bench_daemons also writes
    # early so a later module's crash can't lose the acceptance record)
    write_bench_json(extra={"acceptance": getattr(bench_daemons.run, "acceptance", None)})
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
