"""Paper claim (§7): the adaptive credit system is device-neutral — the
instances of a replicated job get about the same credit regardless of which
host processed them. Reports the mean relative spread of claimed credit
within replicated jobs after normalization warms up."""
from __future__ import annotations

from .common import emit, make_project, submit_jobs, timer

from repro.core import GridSimulation, JobState, make_population, reset_ids


def run() -> None:
    reset_ids()
    server = make_project(min_quorum=2)
    submit_jobs(server, 600)
    # strongly heterogeneous fleet: 4x speed spread, varied efficiency
    pop = make_population(24, seed=8, availability=1.0, speed_spread=0.7)
    sim = GridSimulation(server, pop, seed=2)
    t0 = timer()
    sim.run(8 * 86400.0)
    wall = timer() - t0

    spreads = []
    grants = 0
    for job in server.store.jobs.values():
        if job.state != JobState.SUCCESS:
            continue
        claims = [
            i.claimed_credit
            for i in server.store.job_instances(job.id)
            if i.claimed_credit > 0
        ]
        if len(claims) >= 2:
            m = sum(claims) / len(claims)
            if m > 0:
                spreads.append((max(claims) - min(claims)) / m)
            grants += 1
    # ignore the warm-up phase: normalization needs samples (§7)
    warm = spreads[len(spreads) // 2 :]
    mean_spread = sum(warm) / len(warm) if warm else float("nan")
    emit(
        "credit_device_neutrality",
        wall * 1e6,
        (
            f"replicated_jobs={grants};mean_claim_spread={mean_spread:.3f};"
            f"paper_claim=similar_credit_across_hosts;pass={mean_spread < 0.5}"
        ),
    )


if __name__ == "__main__":
    run()
