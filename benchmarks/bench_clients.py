"""Client-tick cost at large host populations (§6.1–6.2, §9).

The EmBOINC-style emulator models large volunteer populations, but the
scalar client path runs the §6.1 WRR simulation and run-set selection once
per host per event. This benchmark measures one full *client tick* — the
work every host does when sharing a simulator tick: the run-set reschedule
(``Client.schedule``: WRR deadline-miss prediction + ordering + greedy
maximal feasible set) plus the §6.2 work-fetch test (``needs_work``:
another WRR pass for shortfall/idle) — through both engines:

  * ``scalar``  — per-host Python: ``schedule(now)`` + ``needs_work(now)``
                  for every client (exactly what ``GridSimulation`` does
                  without coalescing);
  * ``batch``   — ``BatchClientEngine.tick_batch``: one struct-of-arrays
                  snapshot and one fused WRR pass for the whole population.

The workload models a deep-buffer BOINC client fleet: 4–16 cores, 35% of
hosts with a GPU, 20–40 queued jobs per host (a 0.5-day B_HI buffer of
0.1–2 h jobs), mixed progress/deadlines. The two paths are verified
result-identical on a small population before timing. Per-side times take
the **minimum over alternating rounds** (the standard noise-robust timing
estimator); the scalar side at the 100k population is extrapolated from a
10k-host sample (clients are independent, per-host cost is
population-invariant) and flagged as such.

Acceptance floor: **≥10×** batched-vs-scalar client tick cost at the
10k-host population. Smoke mode (CI): ``--smoke`` / ``BENCH_CLIENTS_SMOKE=1``
trims to a 2000-host population with a 5× floor (CI machine variance) and
asserts it. Results are written to ``benchmarks/BENCH_clients.json``
(machine-readable; schema {schema, rows, acceptance}).
"""
from __future__ import annotations

import gc
import os
import random
import sys
from typing import List, Optional

from .common import RESULTS, emit, timer, write_bench_json

from repro.core import BatchClientEngine, ResourceType
from repro.core.client import (
    Client,
    ClientJob,
    ClientPrefs,
    ClientResource,
    ProjectAttachment,
)

CPU, GPU = ResourceType.CPU, ResourceType.GPU

ACCEPTANCE_FLOOR = 10.0  # x speedup at the 10k-host population
SMOKE_FLOOR = 5.0  # CI machines are slower and noisier
_FLOOR_POP = 10_000


def make_fleet(n_hosts: int, seed: int = 0, max_jobs: int = 40) -> List[Client]:
    """A deep-buffer client fleet mid-run: every host holds 0.5 days of
    queued work for 4–16 cores (§6.2 B_HI), some of it running/preempted."""
    rng = random.Random(seed)
    fleet = []
    for h in range(n_hosts):
        resources = {CPU: ClientResource(CPU, rng.choice([4, 8, 16]), rng.uniform(5e9, 4e10))}
        if rng.random() < 0.35:
            resources[GPU] = ClientResource(GPU, 1, 1e12)
        c = Client(
            host_id=h + 1,
            resources=resources,
            prefs=ClientPrefs(buffer_lo_days=0.05, buffer_hi_days=0.5),
            ram_bytes=8e9,
        )
        c.attach(ProjectAttachment(name="p", resource_share=100.0))
        for i in range(rng.randrange(max_jobs // 2, max_jobs + 1)):
            usage = {CPU: 1.0}
            if GPU in resources and rng.random() < 0.4:
                usage = {CPU: 0.5, GPU: 1.0}
            est_flops = rng.uniform(5e9, 2e10)
            c.jobs.append(ClientJob(
                instance_id=h * 100 + i,
                job_id=h * 100 + i,
                project="p",
                app_name="work",
                usage=usage,
                est_flops=est_flops,
                est_flop_count=rng.uniform(0.1, 2.0) * 3600 * est_flops,
                deadline=rng.uniform(3600.0, 86400.0),
                est_wss=rng.choice([0.0, 0.5e9]),
                fraction_done=rng.choice([0.0, 0.0, 0.4]),
                runtime=rng.uniform(0.0, 1800.0),
            ))
        fleet.append(c)
    return fleet


def _scalar_tick(fleet: List[Client], now: float) -> None:
    for c in fleet:
        c.schedule(now)
        c.needs_work(now)


def _verify_parity(seed: int, now: float) -> None:
    """Refuse to benchmark diverged engines: run sets and work requests
    must be identical on a twin population."""
    a = make_fleet(200, seed, max_jobs=16)
    b = make_fleet(200, seed, max_jobs=16)
    runs_b, needs_b = BatchClientEngine().tick_batch(b, now)
    for ca, rb, nb in zip(a, runs_b, needs_b):
        ra = ca.schedule(now)
        na = ca.needs_work(now)
        assert [j.instance_id for j in ra] == [j.instance_id for j in rb], ca.host_id
        assert na == nb, ca.host_id


def _measure(pop: int, rounds: int, scalar_sample: int) -> tuple:
    """Min-over-rounds seconds per tick for (scalar, batch). The scalar
    side is measured on ``min(pop, scalar_sample)`` hosts and scaled by
    population (per-host independence); returns (scalar_s, batch_s,
    extrapolated)."""
    now = 500.0
    n_scalar = min(pop, scalar_sample)
    extrapolated = n_scalar < pop
    scalar_fleet = make_fleet(n_scalar, seed=3)
    batch_fleet = make_fleet(pop, seed=3)
    engine = BatchClientEngine()
    # the resident fleets are hundreds of thousands of long-lived objects;
    # freeze them out of the cyclic GC so collection sweeps triggered by the
    # engines' allocation bursts don't bill fleet traversal to either side
    gc.collect()
    gc.freeze()
    scalar_s: Optional[float] = None
    batch_s: Optional[float] = None
    try:
        for _ in range(rounds):
            t0 = timer()
            _scalar_tick(scalar_fleet, now)
            t = timer() - t0
            scalar_s = t if scalar_s is None else min(scalar_s, t)
            t0 = timer()
            engine.tick_batch(batch_fleet, now)
            t = timer() - t0
            batch_s = t if batch_s is None else min(batch_s, t)
    finally:
        gc.unfreeze()
    return scalar_s * (pop / n_scalar), batch_s, extrapolated


def run() -> None:
    smoke = "--smoke" in sys.argv or bool(os.environ.get("BENCH_CLIENTS_SMOKE"))
    if smoke:
        populations = (2_000,)
        rounds = 2
        floor = SMOKE_FLOOR
    else:
        populations = (1_000, 10_000, 100_000)
        rounds = 3
        floor = ACCEPTANCE_FLOOR
    floor_pop = populations[-1] if smoke else _FLOOR_POP
    scalar_sample = 10_000

    _verify_parity(11, 500.0)

    start_row = len(RESULTS)
    speedup_at_floor: Optional[float] = None
    for pop in populations:
        scalar_s, batch_s, extrapolated = _measure(pop, rounds, scalar_sample)
        speedup = scalar_s / batch_s if batch_s > 0 else 0.0
        tag = ";scalar_extrapolated=true" if extrapolated else ""
        emit(
            f"clients_tick_scalar_{pop}hosts",
            scalar_s * 1e6,
            f"tick_ms={scalar_s * 1e3:.1f}{tag}",
        )
        emit(
            f"clients_tick_batch_{pop}hosts",
            batch_s * 1e6,
            f"tick_ms={batch_s * 1e3:.1f}",
        )
        is_floor = pop == floor_pop
        emit(
            f"clients_speedup_{pop}hosts",
            0.0,
            f"speedup={speedup:.1f}x"
            + (f";floor={floor:.0f}x;pass={speedup >= floor}" if is_floor else ""),
        )
        if is_floor:
            speedup_at_floor = speedup

    acceptance = {
        "metric": f"client tick speedup at {floor_pop} hosts",
        "floor": floor,
        "measured": speedup_at_floor,
        "pass": (speedup_at_floor or 0.0) >= floor,
        "smoke": smoke,
    }
    run.acceptance = acceptance  # picked up by benchmarks.run and CI
    write_bench_json(
        path=os.environ.get(
            "BENCH_CLIENTS_JSON_PATH",
            os.path.join(os.path.dirname(__file__), "BENCH_clients.json"),
        ),
        rows=RESULTS[start_row:],
        extra={"acceptance": acceptance},
    )
    if smoke and not acceptance["pass"]:
        raise SystemExit(
            f"bench_clients smoke floor failed: {speedup_at_floor:.1f}x < {floor:.0f}x"
        )


if __name__ == "__main__":
    run()
