"""Paper claim (§3.4): plain replication costs >= 2x throughput; adaptive
replication drives the factor toward 1 while keeping the accepted-error rate
low even with malicious volunteers. Streams jobs through the EmBOINC
simulator and reports overhead + error rate for both policies."""
from __future__ import annotations

from .common import emit, make_project, timer

from repro.core import GridSimulation, Job, make_population, next_id, reset_ids


def _run(adaptive: bool, horizon_days: float = 12.0, n_hosts: int = 40,
         wave: int = 120, malicious_fraction: float = 0.05,
         error_prob: float = 0.002):
    reset_ids()
    server = make_project(adaptive=adaptive)
    pop = make_population(
        n_hosts, seed=11, availability=1.0,
        error_prob=error_prob, malicious_fraction=malicious_fraction,
    )
    sim = GridSimulation(server, pop, seed=5)

    def submit(now):
        for _ in range(wave):
            server.submit_job(
                Job(id=next_id("job"), app_name="work", est_flop_count=0.25 * 3600 * 16.5e9),
                now,
            )

    horizon = horizon_days * 86400.0
    t = 0.0
    while t < horizon:
        sim.schedule_callback(t, submit)
        t += 6 * 3600.0
    m = sim.run(horizon)
    sim.audit_validation()
    return m


def run() -> None:
    t0 = timer()
    plain = _run(adaptive=False, horizon_days=6.0)
    adaptive = _run(adaptive=True, horizon_days=12.0)
    wall = timer() - t0
    emit(
        "replication_overhead_plain",
        wall * 1e6,
        f"overhead={plain.replication_overhead:.3f};error_rate={plain.error_rate:.5f}",
    )
    # the paper's claim: overhead moves from >=2 toward 1 and errors stay low
    emit(
        "replication_overhead_adaptive",
        wall * 1e6,
        (
            f"overhead={adaptive.replication_overhead:.3f};"
            f"error_rate={adaptive.error_rate:.5f};"
            f"paper_claim=overhead_to_1;pass={adaptive.replication_overhead < plain.replication_overhead}"
        ),
    )


if __name__ == "__main__":
    run()
