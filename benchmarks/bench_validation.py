"""Validation-engine cost + the §3.4 adaptive-replication claim.

Two measurements live here:

**1. Scalar-vs-batch validate-pass latency** (the PR-4 engine claim).
Builds a store holding 1k / 10k / 100k validation-pending instances and
times one full ``Transitioner.tick`` through both paths:

  * ``scalar`` — ``batch_validate=False``: per-job ``check_set`` pairwise
    comparator loops, per-instance credit/reputation dict updates (the
    parity oracle);
  * ``batch``  — ``batch_validate=True``: the ``core/batch_validate``
    engine — fused SoA gather, digest grouping via one ``(job, digest)``
    lexsort, mask-pass quorum decisions, batched credit ingestion and
    one vectorized reputation pass.

Three §3.4-shaped workloads:

  * ``steady``    — quorum-2 replica pairs, plain-float payloads, 4%
                    corruption: the quiescent-project common case;
  * ``tensor``    — float32[256] gradient-chunk payloads (the grid-trainer
                    shape), quorum 2;
  * ``contested`` — the malicious-host stress the EmBOINC error-rate
                    studies target: 6 successes per job, quorum 3, 40%
                    corrupted outputs → many disagreeing groups, where the
                    scalar comparator count grows O(successes × groups).

Acceptance floor: **≥5×** batch-vs-scalar on the ``contested`` workload at
10k pending instances (target 10×; the scalar side at 100k is extrapolated
from a 10k sample — jobs are independent, per-job cost is
population-invariant). Smoke mode (CI): ``--smoke`` /
``BENCH_VALIDATION_SMOKE=1`` trims to 10k pending, 2 rounds, and asserts
the floor. Results are written to ``benchmarks/BENCH_validation.json``
(schema {schema, rows, acceptance}).

**2. Replication overhead → 1 under adaptive replication** (§3.4, kept
from the seed benchmark): plain replication costs ≥2× throughput; adaptive
replication drives the factor toward 1 while keeping the accepted-error
rate low even with malicious volunteers. Streams jobs through the EmBOINC
simulator and reports overhead + error rate for both policies.
"""
from __future__ import annotations

import gc
import os
import random
import sys
from typing import Optional, Tuple

import numpy as np

from .common import RESULTS, emit, make_project, timer, write_bench_json

from repro.core import (
    AdaptiveReplication,
    App,
    AppVersion,
    CreditSystem,
    GridSimulation,
    Host,
    InstanceOutcome,
    InstanceState,
    Job,
    JobStore,
    Platform,
    ProcessingResource,
    ResourceType,
    Transitioner,
    default_cpu_plan_class,
    fuzzy_comparator,
    make_population,
    next_id,
    reset_ids,
)

ACCEPTANCE_FLOOR = 5.0  # x speedup, contested workload, 10k pending
TARGET = 10.0
_FLOOR_POP = 10_000

#: (successes per job, quorum, corruption probability, payload kind)
WORKLOADS = {
    "steady": (2, 2, 0.04, "float"),
    "tensor": (2, 2, 0.04, "array"),
    "contested": (6, 3, 0.40, "float"),
}


def _build_pending(
    n_pending: int,
    batch_validate: bool,
    workload: str,
    seed: int = 7,
    n_hosts: int = 200,
    dim: int = 256,
) -> Tuple[JobStore, Transitioner]:
    """A store whose jobs all sit at the validation step: every instance
    reported, flagged for transition, quorum reachable."""
    per_job, quorum, bad_frac, payload = WORKLOADS[workload]
    reset_ids()
    rng = random.Random(seed)
    rs = np.random.RandomState(seed)
    store = JobStore()
    app = App(
        name="work",
        min_quorum=quorum,
        init_ninstances=quorum,
        max_success_instances=max(6, per_job + 2),
        comparator=fuzzy_comparator(rtol=1e-6, atol=1e-9),
    )
    vid = next_id("appver")
    app.add_version(
        AppVersion(
            id=vid,
            app_name="work",
            platform=Platform("linux", "x86_64"),
            version_num=1,
            plan_class=default_cpu_plan_class(),
        )
    )
    store.add_app(app)
    for h in range(n_hosts):
        store.add_host(
            Host(
                id=h + 1,
                platforms=(Platform("linux", "x86_64"),),
                resources={
                    ResourceType.CPU: ProcessingResource(
                        ResourceType.CPU, 4, 16.5e9
                    )
                },
                volunteer_id=h + 1,
            )
        )
    for _ in range(max(1, n_pending // per_job)):
        job = Job(
            id=next_id("job"),
            app_name="work",
            est_flop_count=0.2 * 3600 * 16.5e9,
            min_quorum=quorum,
            init_ninstances=quorum,
            max_success_instances=max(6, per_job + 2),
        )
        store.submit_job(job)
        if payload == "float":
            truth = float(job.id) * 1.5
        else:
            truth = rs.standard_normal(dim).astype(np.float32)
        for k in range(per_job):
            inst = store.create_instance(job)
            inst.host_id = rng.randrange(n_hosts) + 1
            inst.app_version_id = vid
            inst.state = InstanceState.IN_PROGRESS
            inst.state = InstanceState.OVER
            inst.outcome = InstanceOutcome.SUCCESS
            inst.runtime = 700.0 + rng.random() * 100
            inst.peak_flop_count = inst.runtime * 16.5e9
            corrupt = rng.random() < bad_frac if workload == "contested" else (
                k > 0 and rng.random() < bad_frac
            )
            if corrupt:
                if payload == "float":
                    inst.output = truth + rng.uniform(1.0, 2.0)
                else:
                    inst.output = truth + rs.uniform(1, 2, size=dim).astype(np.float32)
            else:
                inst.output = truth
    tr = Transitioner(
        store=store,
        credit=CreditSystem(),
        adaptive=AdaptiveReplication(),
        batch_validate=batch_validate,
    )
    return store, tr


def _verify_parity(workload: str) -> None:
    """Refuse to benchmark diverged engines: states, credit, metrics, and
    reputation must be identical on a twin store."""
    # tick each twin right after building it: _build_pending resets the
    # global id counters, so a tick's top-up instances must be created
    # before the other twin rewinds the sequence
    sa, ta = _build_pending(1200, False, workload)
    ta.tick(60.0)
    sb, tb = _build_pending(1200, True, workload)
    tb.tick(60.0)
    snap_a = {
        i: (x.validate_state, x.claimed_credit, x.granted_credit, x.outcome)
        for i, x in sa.instances.items()
    }
    snap_b = {
        i: (x.validate_state, x.claimed_credit, x.granted_credit, x.outcome)
        for i, x in sb.instances.items()
    }
    assert snap_a == snap_b, f"instance divergence ({workload})"
    assert {j: (x.state, x.canonical_instance_id) for j, x in sa.jobs.items()} == {
        j: (x.state, x.canonical_instance_id) for j, x in sb.jobs.items()
    }, f"job divergence ({workload})"
    assert vars(ta.metrics) == vars(tb.metrics), f"metrics divergence ({workload})"
    assert ta.credit.total == tb.credit.total, f"credit divergence ({workload})"
    assert (
        ta.adaptive.consecutive_valid == tb.adaptive.consecutive_valid
    ), f"reputation divergence ({workload})"
    sb.check_invariants()


def _measure(
    workload: str, pop: int, rounds: int, scalar_sample: int
) -> Tuple[float, float, bool]:
    """Min-over-rounds seconds per validate-pass tick for (scalar, batch).
    A tick consumes its pending work, so every round rebuilds the store;
    the resident stores are frozen out of the cyclic GC while timing. The
    scalar side is measured on min(pop, scalar_sample) instances and
    scaled (jobs are independent)."""
    n_scalar = min(pop, scalar_sample)
    extrapolated = n_scalar < pop
    scalar_s: Optional[float] = None
    batch_s: Optional[float] = None
    for _ in range(rounds):
        for mode, n in ((False, n_scalar), (True, pop)):
            store, tr = _build_pending(n, mode, workload)
            gc.collect()
            gc.freeze()
            gc.disable()
            t0 = timer()
            tr.tick(60.0)
            t = timer() - t0
            gc.enable()
            gc.unfreeze()
            if mode:
                batch_s = t if batch_s is None else min(batch_s, t)
            else:
                scalar_s = t if scalar_s is None else min(scalar_s, t)
            del store, tr
    return scalar_s * (pop / n_scalar), batch_s, extrapolated


def _bench_engine(smoke: bool) -> dict:
    if smoke:
        populations: Tuple[int, ...] = (10_000,)
        rounds = 2
        workloads = ("contested", "steady")
    else:
        populations = (1_000, 10_000, 100_000)
        rounds = 3
        workloads = ("steady", "tensor", "contested")
    floor_pop = populations[-1] if smoke else _FLOOR_POP
    scalar_sample = 10_000

    for w in workloads:
        _verify_parity(w)

    speedup_at_floor: Optional[float] = None
    for workload in workloads:
        pops = populations if workload == "contested" else populations[:2]
        for pop in pops:
            scalar_s, batch_s, extrapolated = _measure(
                workload, pop, rounds, scalar_sample
            )
            speedup = scalar_s / batch_s if batch_s > 0 else 0.0
            tag = ";scalar_extrapolated=true" if extrapolated else ""
            emit(
                f"validate_tick_scalar_{workload}_{pop}",
                scalar_s * 1e6,
                f"tick_ms={scalar_s * 1e3:.1f}{tag}",
            )
            emit(
                f"validate_tick_batch_{workload}_{pop}",
                batch_s * 1e6,
                f"tick_ms={batch_s * 1e3:.1f}",
            )
            is_floor = workload == "contested" and pop == floor_pop
            emit(
                f"validate_speedup_{workload}_{pop}",
                0.0,
                f"speedup={speedup:.1f}x"
                + (
                    f";floor={ACCEPTANCE_FLOOR:.0f}x;target={TARGET:.0f}x"
                    f";pass={speedup >= ACCEPTANCE_FLOOR}"
                    if is_floor
                    else ""
                ),
            )
            if is_floor:
                speedup_at_floor = speedup

    return {
        "metric": f"validate-pass tick speedup, contested workload, {floor_pop} pending instances",
        "floor": ACCEPTANCE_FLOOR,
        "target": TARGET,
        "measured": speedup_at_floor,
        "pass": (speedup_at_floor or 0.0) >= ACCEPTANCE_FLOOR,
        "smoke": smoke,
    }


# ---------------------------------------------------------------------------
# §3.4 adaptive-replication claim (seed benchmark, kept)
# ---------------------------------------------------------------------------


def _run_replication(adaptive: bool, horizon_days: float = 12.0, n_hosts: int = 40,
                     wave: int = 120, malicious_fraction: float = 0.05,
                     error_prob: float = 0.002):
    reset_ids()
    server = make_project(adaptive=adaptive)
    pop = make_population(
        n_hosts, seed=11, availability=1.0,
        error_prob=error_prob, malicious_fraction=malicious_fraction,
    )
    sim = GridSimulation(server, pop, seed=5)

    def submit(now):
        for _ in range(wave):
            server.submit_job(
                Job(id=next_id("job"), app_name="work", est_flop_count=0.25 * 3600 * 16.5e9),
                now,
            )

    horizon = horizon_days * 86400.0
    t = 0.0
    while t < horizon:
        sim.schedule_callback(t, submit)
        t += 6 * 3600.0
    m = sim.run(horizon)
    sim.audit_validation()
    return m


def _bench_replication_claim() -> None:
    t0 = timer()
    plain = _run_replication(adaptive=False, horizon_days=6.0)
    adaptive = _run_replication(adaptive=True, horizon_days=12.0)
    wall = timer() - t0
    emit(
        "replication_overhead_plain",
        wall * 1e6,
        f"overhead={plain.replication_overhead:.3f};error_rate={plain.error_rate:.5f}",
    )
    # the paper's claim: overhead moves from >=2 toward 1 and errors stay low
    emit(
        "replication_overhead_adaptive",
        wall * 1e6,
        (
            f"overhead={adaptive.replication_overhead:.3f};"
            f"error_rate={adaptive.error_rate:.5f};"
            f"paper_claim=overhead_to_1;pass={adaptive.replication_overhead < plain.replication_overhead}"
        ),
    )


def run() -> None:
    smoke = "--smoke" in sys.argv or bool(os.environ.get("BENCH_VALIDATION_SMOKE"))
    start_row = len(RESULTS)
    acceptance = _bench_engine(smoke)
    if not smoke:
        _bench_replication_claim()
    run.acceptance = acceptance  # picked up by benchmarks.run and CI
    write_bench_json(
        path=os.environ.get(
            "BENCH_VALIDATION_JSON_PATH",
            os.path.join(os.path.dirname(__file__), "BENCH_validation.json"),
        ),
        rows=RESULTS[start_row:],
        extra={"acceptance": acceptance},
    )
    if smoke and not acceptance["pass"]:
        raise SystemExit(
            f"bench_validation smoke floor failed: "
            f"{acceptance['measured']:.1f}x < {ACCEPTANCE_FLOOR:.0f}x"
        )


if __name__ == "__main__":
    run()
