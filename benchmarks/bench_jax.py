"""JAX execution backend vs the NumPy engines at 1M-host scale (ISSUE 9).

Times the two dense passes the ``backend="jax"`` tentpole moved on-device,
against the NumPy engine branches they mirror bit-for-bit:

  * **dispatch scoring** — the §6.4 base-score + runtime-estimate kernel
    over a 1M-candidate masked set (``jax_backend.dispatch_scores`` vs the
    ``BatchDispatchEngine.candidate_rows`` NumPy branch, replicated inline
    with identical IEEE op order);
  * **world accrual tick** — the fused clamped-charge pass over a 1M-host
    columnar world (``HostArrays._advance_cols`` on a ``backend="jax"``
    world — device-resident column mirrors, dirty-range uploads, donated
    buffers — vs the same method's NumPy K-loop).

Parity is asserted bitwise at a small population before timing (refuse to
benchmark diverged backends). Worlds are assembled synthetically (columns
filled directly, no per-host Python objects) so 1M hosts build in seconds;
the accrual pass is timed through ``_advance_cols`` on precomputed active
slots, isolating the kernel both backends share from the per-host id
bookkeeping that is identical on either side.

Acceptance floor (CI, ``--smoke`` / ``BENCH_JAX_SMOKE=1``): the JAX world
accrual pass must stay within **4x** of the NumPy pass wall-clock at the
smoke population. This is deliberately a *within-factor* floor, not a
speedup floor: on a small CPU (CI runs single-core CPU jax) XLA's
dispatch overhead and lack of in-place column mutation make parity-to-
modest-slowdown the honest expectation — the backend targets wide SIMD
units and accelerators, where the same staged jits fuse into a handful of
device passes. Results go to ``benchmarks/BENCH_jax.json``
(schema {schema, rows, acceptance}).
"""
from __future__ import annotations

import os
import sys
from typing import Optional

import numpy as np

from .common import RESULTS, emit, timer, write_bench_json

from repro.core import ResourceType
from repro.core.jax_backend import HAVE_JAX, dispatch_scores
from repro.core.scheduler import W_BALANCE, W_KEYWORD, W_PRIORITY, W_SKIPPED
from repro.core.world import HostArrays

CPU = ResourceType.CPU

#: CI floor: jax accrual pass wall-clock <= FLOOR_FACTOR * numpy pass.
FLOOR_FACTOR = 4.0
TICKS = 10  # timed accrual ticks (post-warmup) per backend


# ---------------------------------------------------------------------------
# dispatch scoring
# ---------------------------------------------------------------------------


def _score_inputs(n: int, seed: int = 7):
    rs = np.random.RandomState(seed)
    return (
        rs.rand(n) < 0.5,  # kvec
        rs.uniform(-10, 10, n),  # bal
        rs.uniform(-5, 5, n),  # prio
        rs.randint(0, 9, n).astype(np.float64),  # skips
        rs.uniform(1e9, 1e14, n),  # flop
        np.where(rs.rand(n) < 0.1, 0.0, rs.uniform(1e8, 1e11, n)),  # pf
        0.8,  # avail
    )


def _np_scores(kvec, bal, prio, skips, flop, pf, avail):
    """Inline replica of the engine's NumPy scoring branch (same op order)."""
    scores = W_KEYWORD * kvec
    scores += W_BALANCE * bal
    scores += W_PRIORITY * prio
    scores += W_SKIPPED * np.minimum(skips, 5.0)
    est = np.full(kvec.shape, np.inf, dtype=np.float64)
    pos = pf > 0.0
    est[pos] = flop[pos] / pf[pos]
    scaled = est / avail if avail > 0 else np.full(kvec.shape, np.inf)
    return scores, est, scaled


def _bench_scoring(n: int):
    inp = _score_inputs(n)
    weights = (W_KEYWORD, W_BALANCE, W_PRIORITY, W_SKIPPED)

    want = _np_scores(*inp)
    got = dispatch_scores(*inp, weights)
    for a, b in zip(got, want):
        assert np.array_equal(a, b), "scoring backends diverged"

    t0 = timer()
    for _ in range(TICKS):
        _np_scores(*inp)
    np_s = (timer() - t0) / TICKS

    t0 = timer()
    for _ in range(TICKS):
        dispatch_scores(*inp, weights)
    jx_s = (timer() - t0) / TICKS

    emit(f"jax_dispatch_scores_numpy_{n}", np_s * 1e6, f"wall_ms={np_s * 1e3:.1f}")
    emit(f"jax_dispatch_scores_jax_{n}", jx_s * 1e6, f"wall_ms={jx_s * 1e3:.1f}")
    emit(
        f"jax_dispatch_scores_ratio_{n}", 0.0,
        f"jax_over_numpy={jx_s / np_s:.2f}x",
    )


# ---------------------------------------------------------------------------
# world accrual tick
# ---------------------------------------------------------------------------


def _mk_world(backend: str, n_hosts: int, K: int = 4, seed: int = 3) -> HostArrays:
    """Synthetic columnar world: columns filled directly (no per-host
    Python objects) so million-host populations build in seconds. Clients
    stay ``None`` — the REC flush is per-host Python identical on both
    backends and is not what this bench isolates."""
    rs = np.random.RandomState(seed)
    world = HostArrays(backend=backend)
    world._grow_hosts(n_hosts)
    world._grow_queue(K)
    world.n = n_hosts
    world.ids[:n_hosts] = np.arange(1, n_hosts + 1)
    world.index = {h + 1: h for h in range(n_hosts)}
    world.alive[:n_hosts] = True
    world.available[:n_hosts] = rs.rand(n_hosts) < 0.95
    world.clients = [None] * n_hosts
    world.queue_jobs = [[] for _ in range(n_hosts)]
    world.row_of = [{} for _ in range(n_hosts)]
    world.project = [None] * n_hosts
    world.multi = [False] * n_hosts
    counts = rs.randint(1, K + 1, n_hosts)
    world.q_count[:n_hosts] = counts
    Q = world._q
    rowmask = np.arange(Q)[:, None] < counts[None, :]
    tot = np.where(rowmask, rs.uniform(3600.0, 7 * 86400.0, (Q, n_hosts)), 0.0)
    run = np.where(rowmask, tot * rs.rand(Q, n_hosts) * 0.5, 0.0)
    world.q_total[:, :n_hosts] = tot
    world.q_runtime[:, :n_hosts] = run
    world.q_frac[:, :n_hosts] = np.where(rowmask, run / np.maximum(tot, 1e-9), 0.0)
    world.q_running[:, :n_hosts] = rowmask & (rs.rand(Q, n_hosts) < 0.7)
    world.q_weight[:, :n_hosts] = np.where(rowmask, 1.0, 0.0)
    world.q_usage[CPU][:, :n_hosts] = np.where(
        rowmask, rs.choice([0.5, 1.0, 2.0], (Q, n_hosts)), 0.0
    )
    return world


def _active(world: HostArrays, n_hosts: int, seed: int = 5):
    rs = np.random.RandomState(seed)
    act = world.available[:n_hosts] & (rs.rand(n_hosts) < 0.9)
    sub = np.flatnonzero(act)
    dts = rs.uniform(30.0, 90.0, len(sub))
    return sub, dts


def _verify_parity(n_hosts: int = 10_000) -> None:
    """Refuse to benchmark diverged backends: a few accrual passes over
    twin synthetic worlds must leave bit-identical columns and debits."""
    wn = _mk_world("numpy", n_hosts)
    wj = _mk_world("jax", n_hosts)
    for tick in range(3):
        sub, dts = _active(wn, n_hosts, seed=5 + tick)
        dn, tn = wn._advance_cols(sub, dts)
        dj, tj = wj._advance_cols(sub, dts)
        assert np.array_equal(dn, dj) and np.array_equal(tn, tj)
    assert np.array_equal(wn.q_runtime, wj.q_runtime)
    assert np.array_equal(wn.q_frac, wj.q_frac)
    assert np.array_equal(wn.busy, wj.busy)


def _bench_world(n_hosts: int) -> float:
    sub, dts = _active(_mk_world("numpy", n_hosts), n_hosts)

    wn = _mk_world("numpy", n_hosts)
    wn._advance_cols(sub, dts)  # warm page cache symmetrically
    t0 = timer()
    for _ in range(TICKS):
        wn._advance_cols(sub, dts)
    np_s = (timer() - t0) / TICKS

    wj = _mk_world("jax", n_hosts)
    wj._advance_cols(sub, dts)  # warmup: full upload + jit compile
    t0 = timer()
    for _ in range(TICKS):
        wj._advance_cols(sub, dts)
    jx_s = (timer() - t0) / TICKS

    ratio = jx_s / np_s if np_s > 0 else float("inf")
    emit(f"jax_world_tick_numpy_{n_hosts}hosts", np_s * 1e6, f"wall_ms={np_s * 1e3:.1f}")
    emit(f"jax_world_tick_jax_{n_hosts}hosts", jx_s * 1e6, f"wall_ms={jx_s * 1e3:.1f}")
    emit(
        f"jax_world_tick_ratio_{n_hosts}hosts", 0.0,
        f"jax_over_numpy={ratio:.2f}x;floor={FLOOR_FACTOR:.1f}x;pass={ratio <= FLOOR_FACTOR}",
    )
    return ratio


def run() -> None:
    if not HAVE_JAX:
        emit("jax_backend_unavailable", 0.0, "skipped=jax_not_importable")
        run.acceptance = {
            "metric": "jax backend benchmark", "pass": True,
            "skipped": "jax not importable",
        }
        return
    smoke = "--smoke" in sys.argv or bool(os.environ.get("BENCH_JAX_SMOKE"))
    n_score = 1 << 17 if smoke else 1 << 20  # 1M candidates full
    n_hosts = 100_000 if smoke else 1_000_000

    _verify_parity()

    start_row = len(RESULTS)
    _bench_scoring(n_score)
    ratio = _bench_world(n_hosts)

    acceptance = {
        "metric": f"jax world accrual pass within {FLOOR_FACTOR:.0f}x of numpy "
                  f"at {n_hosts} hosts (CPU; accelerator-targeted backend)",
        "floor_factor": FLOOR_FACTOR,
        "measured_ratio": ratio,
        "pass": ratio <= FLOOR_FACTOR,
        "smoke": smoke,
    }
    run.acceptance = acceptance  # picked up by benchmarks.run and CI
    write_bench_json(
        path=os.environ.get(
            "BENCH_JAX_JSON_PATH",
            os.path.join(os.path.dirname(__file__), "BENCH_jax.json"),
        ),
        rows=RESULTS[start_row:],
        extra={"acceptance": acceptance},
    )
    if smoke and not acceptance["pass"]:
        raise SystemExit(
            f"bench_jax smoke floor failed: {ratio:.2f}x > {FLOOR_FACTOR:.1f}x"
        )


if __name__ == "__main__":
    run()
