"""Paper claim [§5.1, ref 17]: the shared-memory job-cache architecture lets
a single server "dispatch hundreds of jobs per second". Measures wall-clock
dispatch throughput of the real scheduler + feeder against a synthetic host
fleet, and batch-submission latency ("submitting a batch of a thousand jobs
takes less than a second", §3.9)."""
from __future__ import annotations

from .common import emit, make_project, submit_jobs, timer

from repro.core import (
    Host,
    Platform,
    ProcessingResource,
    ResourceRequest,
    ResourceType,
    ScheduleRequest,
    next_id,
    reset_ids,
)


def run() -> None:
    reset_ids()
    server = make_project(min_quorum=1)
    hosts = []
    for i in range(64):
        h = Host(
            id=i + 1,
            platforms=(Platform("windows", "x86_64"),),
            resources={ResourceType.CPU: ProcessingResource(ResourceType.CPU, 8, 2e10)},
            volunteer_id=i + 1,
        )
        server.add_host(h)
        hosts.append(h)

    # batch submission latency (§3.9)
    t0 = timer()
    submit_jobs(server, 1000)
    submit_s = timer() - t0
    emit("submit_batch_1000", submit_s * 1e6 / 1000.0, f"batch_submit_s={submit_s:.3f}")

    server.tick(0.0)

    # dispatch throughput: hosts request work until the queue drains
    dispatched = 0
    rpcs = 0
    t0 = timer()
    now = 0.0
    while dispatched < 1000 and rpcs < 4000:
        for h in hosts:
            req = ScheduleRequest(
                host_id=h.id,
                requests={ResourceType.CPU: ResourceRequest(req_runtime=2e4, req_idle=8)},
            )
            reply = server.rpc(req, now)
            rpcs += 1
            dispatched += len(reply.jobs)
            now += 1e-3
            if dispatched >= 1000:
                break
        server.feeder.fill()
    wall = timer() - t0
    rate = dispatched / wall if wall > 0 else 0.0
    emit(
        "dispatch_throughput",
        wall * 1e6 / max(dispatched, 1),
        f"jobs_per_s={rate:.0f};paper_claim=hundreds_per_s;pass={rate >= 300}",
    )


if __name__ == "__main__":
    run()
