"""Paper claim [§5.1, ref 17]: the shared-memory job-cache architecture lets
a single server "dispatch hundreds of jobs per second". Measures wall-clock
dispatch throughput of the real scheduler + feeder against a synthetic host
fleet, and batch-submission latency ("submitting a batch of a thousand jobs
takes less than a second", §3.9).

Also benchmarks the vectorized batch-dispatch engine
(``core/batch_dispatch.py``) against the scalar reference path at 1k / 10k /
100k-host populations: same jobs, same request shape, same feeder refill
cadence — only the dispatch engine differs. Acceptance floor: ≥5× dispatch
throughput for the batch path at the 10k-host population.

Smoke mode (CI): ``python -m benchmarks.bench_dispatch --smoke`` or
``BENCH_DISPATCH_SMOKE=1`` trims the populations to 256 hosts.

Results are written to ``benchmarks/BENCH_dispatch.json`` (machine-readable;
schema {schema, rows, acceptance}) like the other engine benchmarks.
"""
from __future__ import annotations

import os
import sys
from typing import Optional

from .common import RESULTS, emit, make_project, submit_jobs, timer, write_bench_json

from repro.core import (
    Host,
    Platform,
    ProcessingResource,
    ResourceRequest,
    ResourceType,
    ScheduleRequest,
    next_id,
    reset_ids,
)

# one dispatch per request: the tiny runtime shortfall is satisfied by the
# first job sent, so throughput == requests served per second
_REQ = {ResourceType.CPU: ResourceRequest(req_runtime=1.0, req_idle=0)}


def _make_hosts(server, n: int):
    hosts = []
    for i in range(n):
        h = Host(
            id=i + 1,
            platforms=(Platform("windows", "x86_64"),),
            resources={ResourceType.CPU: ProcessingResource(ResourceType.CPU, 8, 2e10)},
            volunteer_id=i + 1,
        )
        server.add_host(h)
        hosts.append(h)
    return hosts


def _request(host) -> ScheduleRequest:
    return ScheduleRequest(host_id=host.id, requests=_REQ)


def _measure_scalar(n_hosts: int, n_requests: int, refill_every: int) -> float:
    """Dispatches/second through the scalar per-request path."""
    reset_ids()
    server = make_project(min_quorum=1)
    hosts = _make_hosts(server, n_hosts)
    submit_jobs(server, n_requests + server.cache_size)
    server.tick(0.0)
    dispatched = 0
    now = 0.0
    t0 = timer()
    for k in range(n_requests):
        reply = server.rpc(_request(hosts[k % n_hosts]), now)
        dispatched += len(reply.jobs)
        now += 1e-3
        if (k + 1) % refill_every == 0:
            server.feeder.fill()
    wall = timer() - t0
    return dispatched / wall if wall > 0 else 0.0


def _measure_batch(n_hosts: int, n_requests: int, chunk_size: int) -> float:
    """Dispatches/second through rpc_batch + the vectorized engine, with a
    feeder refill between chunks (inside the timed region, like scalar)."""
    reset_ids()
    server = make_project(min_quorum=1)
    hosts = _make_hosts(server, n_hosts)
    submit_jobs(server, n_requests + server.cache_size)
    server.tick(0.0)
    dispatched = 0
    now = 0.0
    t0 = timer()
    for base in range(0, n_requests, chunk_size):
        chunk = [
            _request(hosts[k % n_hosts])
            for k in range(base, min(base + chunk_size, n_requests))
        ]
        replies = server.rpc_batch(chunk, now)
        dispatched += sum(len(r.jobs) for r in replies)
        now += 1e-3
        server.feeder.fill()
    wall = timer() - t0
    return dispatched / wall if wall > 0 else 0.0


def _compare_populations(smoke: bool) -> dict:
    """§5.1 at scale: scalar vs vectorized engines over growing host fleets.

    The scalar reference path costs O(cache²) Python per request (the
    skipped-count lookup rescans the cache per scored slot), so it is
    measured over fewer requests; rates are steady-state dispatches/second
    either way. Each request drains one of ~1024 cache slots; the scalar
    run refills every 32 requests (occupancy ≥97%) while the batch run
    refills only between 256-request chunks (occupancy can dip to 75%, a
    slight handicap for the batch path), refills timed in both.
    """
    populations = (256,) if smoke else (1_000, 10_000, 100_000)
    n_scalar = 24 if smoke else 96
    n_batch = 256 if smoke else 2048
    scalar_refill = 8 if smoke else 32
    chunk = 64 if smoke else 256
    floor_pop = populations[-1] if smoke else 10_000
    floor = 2.0 if smoke else 5.0
    speedup_at_floor: Optional[float] = None
    for pop in populations:
        scalar_rate = _measure_scalar(pop, n_scalar, scalar_refill)
        batch_rate = _measure_batch(pop, n_batch, chunk)
        speedup = batch_rate / scalar_rate if scalar_rate > 0 else 0.0
        emit(
            f"dispatch_scalar_{pop}hosts",
            1e6 / max(scalar_rate, 1e-9),
            f"jobs_per_s={scalar_rate:.0f}",
        )
        emit(
            f"dispatch_batch_{pop}hosts",
            1e6 / max(batch_rate, 1e-9),
            f"jobs_per_s={batch_rate:.0f}",
        )
        is_floor = pop == floor_pop
        emit(
            f"dispatch_speedup_{pop}hosts",
            0.0,
            f"speedup={speedup:.1f}x"
            + (f";floor={floor:.0f}x;pass={speedup >= floor}" if is_floor else ""),
        )
        if is_floor:
            speedup_at_floor = speedup
    return {
        "metric": f"dispatch throughput speedup at {floor_pop} hosts",
        "floor": floor,
        "measured": speedup_at_floor,
        "pass": (speedup_at_floor or 0.0) >= floor,
        "smoke": smoke,
    }


def run() -> None:
    start_row = len(RESULTS)
    reset_ids()
    server = make_project(min_quorum=1)
    hosts = _make_hosts(server, 64)

    # batch submission latency (§3.9)
    t0 = timer()
    submit_jobs(server, 1000)
    submit_s = timer() - t0
    emit("submit_batch_1000", submit_s * 1e6 / 1000.0, f"batch_submit_s={submit_s:.3f}")

    server.tick(0.0)

    # dispatch throughput: hosts request work until the queue drains
    dispatched = 0
    rpcs = 0
    t0 = timer()
    now = 0.0
    while dispatched < 1000 and rpcs < 4000:
        for h in hosts:
            req = ScheduleRequest(
                host_id=h.id,
                requests={ResourceType.CPU: ResourceRequest(req_runtime=2e4, req_idle=8)},
            )
            reply = server.rpc(req, now)
            rpcs += 1
            dispatched += len(reply.jobs)
            now += 1e-3
            if dispatched >= 1000:
                break
        server.feeder.fill()
    wall = timer() - t0
    rate = dispatched / wall if wall > 0 else 0.0
    emit(
        "dispatch_throughput",
        wall * 1e6 / max(dispatched, 1),
        f"jobs_per_s={rate:.0f};paper_claim=hundreds_per_s;pass={rate >= 300}",
    )

    smoke = "--smoke" in sys.argv or bool(os.environ.get("BENCH_DISPATCH_SMOKE"))
    acceptance = _compare_populations(smoke)
    run.acceptance = acceptance  # picked up by benchmarks.run and CI
    write_bench_json(
        path=os.environ.get(
            "BENCH_DISPATCH_JSON_PATH",
            os.path.join(os.path.dirname(__file__), "BENCH_dispatch.json"),
        ),
        rows=RESULTS[start_row:],
        extra={"acceptance": acceptance},
    )
    if smoke and not acceptance["pass"]:
        raise SystemExit(
            f"bench_dispatch smoke floor failed: {acceptance['measured']:.1f}x"
            f" < {acceptance['floor']:.0f}x"
        )


if __name__ == "__main__":
    run()
