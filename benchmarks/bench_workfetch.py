"""Paper mechanism (§6.2): B_LO/B_HI buffering bounds the scheduler RPC rate
while keeping processing resources busy through server outages."""
from __future__ import annotations

from .common import emit, make_project, submit_jobs, timer

from repro.core import GridSimulation, make_population, reset_ids


def _run(buffer_days):
    reset_ids()
    server = make_project(min_quorum=1)
    pop = make_population(16, seed=2, availability=1.0)
    sim = GridSimulation(server, pop, seed=4)
    for c in sim.clients.values():
        c.prefs.buffer_lo_days = buffer_days[0]
        c.prefs.buffer_hi_days = buffer_days[1]
    # steady-state: work never dries up
    horizon = 2 * 86400.0
    t = 0.0
    while t < horizon:
        sim.schedule_callback(t, lambda now: submit_jobs(
            server, 600, est_flops=0.1 * 3600 * 16.5e9, now=now))
        t += 3 * 3600.0
    m = sim.run(horizon)
    fetch_per_host_hour = m.rpcs_requesting_work / (16 * horizon / 3600.0)
    return fetch_per_host_hour, m.idle_fraction


def run() -> None:
    t0 = timer()
    small = _run((0.01, 0.02))  # tiny buffer: frequent RPCs
    big = _run((0.2, 0.8))  # deep buffer: rare RPCs
    wall = timer() - t0
    emit(
        "workfetch_rpc_rate",
        wall * 1e6,
        (
            f"rpc_per_host_hour_small_buf={small[0]:.2f};big_buf={big[0]:.2f};"
            f"idle_small={small[1]:.3f};idle_big={big[1]:.3f};"
            f"paper_claim=buffering_cuts_rpc_rate;pass={big[0] <= small[0]}"
        ),
    )


if __name__ == "__main__":
    run()
